#!/usr/bin/env bash
# Tier-1 verify + benchmark smoke. Run from the repo root.
#
# NOTE: 5 seed-era tests are known-failing (dryrun x2, hlo_analysis x2,
# moe_shard_map x1 — jax.shard_map API drift); the exit code goes red until
# a PR fixes them, but the benchmark smoke still runs so every CI log has
# the full picture.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== smoke: offline throughput benchmark (quick) =="
python benchmarks/offline_throughput.py --quick || exit 1

echo "== smoke: EPD serve example (streaming + mm-token cache) =="
python examples/epd_serve.py --requests 4 --new-tokens 4 || exit 1

echo "== smoke: engine TTFT + mm-cache-hit benchmark (quick) =="
python benchmarks/ttft.py --quick --engine-only || exit 1

echo "== smoke: mixed-load scheduler (long prefill mid-decode, chunked) =="
# asserts decode keeps emitting while the long prompt chunk-prefills, the
# unchunked baseline stalls, stop-token requests finish with "stop", and
# the quick run stays under its wall-clock bound
python benchmarks/mixed_load.py --quick || exit 1

echo "CI done (tier-1 exit: $tier1)"
exit "$tier1"
