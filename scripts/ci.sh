#!/usr/bin/env bash
# Tiered CI. Run from the repo root:
#
#   scripts/ci.sh          # fast tier (default): lint + unit + parity, < 2 min
#   scripts/ci.sh lint     # reprolint only: concurrency + JIT-safety passes
#   scripts/ci.sh full     # full tier: whole suite (~10 min) + benchmarks
#
# The lint tier is the repo-specific static analysis (python -m
# repro.analysis): lock-order/blocking-under-lock checks and JIT-safety
# heuristics, gated on the committed analysis_baseline.json. The fast
# tier runs it first (seconds, no jax compilation), then the inner-loop
# checks: pure-python unit tests, the ClusterEngine("1EPD") greedy
# bit-identical parity test, and a pallas (interpret) backend smoke so
# the non-default attention backend cannot silently rot. The full tier
# is what a merge gate runs — the entire pytest suite (including the
# `slow`-marked cluster soak tests), one concurrency-heavy module under
# the runtime lock-order sanitizer, and the benchmark smokes.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER="${1:-fast}"

if [ "$TIER" = "lint" ]; then
    echo "== lint tier: reprolint (concurrency + JIT-safety) =="
    python -m repro.analysis src tests
    exit $?
fi

if [ "$TIER" = "fast" ]; then
    echo "== fast tier: reprolint (concurrency + JIT-safety) =="
    python -m repro.analysis src tests || exit $?
    echo "== fast tier: unit + cluster parity (target < 2 min) =="
    python -m pytest -q -m "not slow" \
        tests/test_block_manager.py \
        tests/test_simulator.py \
        tests/test_api_load.py \
        tests/test_scheduler.py \
        tests/test_fault_injection.py \
        "tests/test_runner.py::test_registry_names_and_validation" \
        "tests/test_runner.py::test_packed_vs_two_program_greedy_bit_identical" \
        "tests/test_cluster_engine.py::test_1epd_greedy_parity_bit_identical" \
        "tests/test_cluster_engine.py::test_spec_and_config_validation" \
        "tests/test_prefix_cache.py::test_cache_on_off_bit_identity_single_engine[packed]" \
        "tests/test_overlap.py::test_overlap_greedy_bit_identity[packed-overlap]" \
        || exit $?
    echo "== fast tier: prefix_cache=on engine smoke (fully-cached admit) =="
    python -m pytest -q \
        "tests/test_prefix_cache.py::test_fully_cached_prefix_runs_zero_prefill_rows" \
        || exit $?
    echo "== fast tier: HTTP gateway smoke (ephemeral port: unary + SSE + 400) =="
    python -m pytest -q \
        "tests/test_gateway.py::test_gateway_smoke" \
        || exit $?
    echo "== fast tier: dead-instance failover parity (byte-exact re-home) =="
    python -m pytest -q \
        "tests/test_fault_injection.py::test_mid_decode_death_bit_parity[kv-migrate]" \
        || exit $?
    echo "== fast tier: pallas-backend engine smoke (interpret) =="
    REPRO_ATTN_BACKEND=pallas python -m pytest -q \
        "tests/test_runner.py::test_env_backend_engine_smoke"
    exit $?
fi

if [ "$TIER" != "full" ]; then
    echo "usage: scripts/ci.sh [fast|lint|full]" >&2
    exit 2
fi

echo "== full tier: reprolint (concurrency + JIT-safety) =="
python -m repro.analysis src tests || exit 1

echo "== tier-1: pytest (full suite, includes slow cluster soak) =="
python -m pytest -q
tier1=$?

echo "== sanitizer: role-switch cluster suite under REPRO_LOCK_SANITIZER =="
# the most concurrency-heavy module (instance executors + monitor thread
# + live role switches); the conftest session fixture fails the run on
# any lock-hierarchy violation
REPRO_LOCK_SANITIZER=1 python -m pytest -q tests/test_cluster_switch.py \
    || exit 1

echo "== sanitizer: fault-injection suite under REPRO_LOCK_SANITIZER =="
# death/failover sweeps + elastic add/remove exercise the supervisor
# thread against live executors — the new lock edges must stay ordered
REPRO_LOCK_SANITIZER=1 python -m pytest -q tests/test_fault_injection.py \
    || exit 1

echo "== sanitizer: encode-prefill overlap suite under REPRO_LOCK_SANITIZER =="
# streaming ψ_EP publishes shard spans from encode workers while the
# scheduler thread polls watermarks — ShardStream._lock must stay a leaf
REPRO_LOCK_SANITIZER=1 python -m pytest -q tests/test_overlap.py \
    || exit 1

echo "== smoke: offline throughput benchmark (quick) =="
python benchmarks/offline_throughput.py --quick || exit 1

echo "== smoke: EPD serve example (streaming + mm-token cache) =="
python examples/epd_serve.py --requests 4 --new-tokens 4 || exit 1

echo "== smoke: cluster serve example (2E1P1D, migrations) =="
python examples/cluster_serve.py --requests 4 --new-tokens 4 || exit 1

echo "== smoke: engine TTFT + mm-cache + prefix-cache + overlap benchmark (quick) =="
# includes the engine_prefix_cache/{off,on} multi-turn rows and the
# engine_overlap/{off,on} many-image rows (TTFT floor must be strictly
# lower overlap-on); the whole engine-only sweep must stay under the
# 10-minute wall-clock bound
timeout 600 python benchmarks/ttft.py --quick --engine-only || exit 1

echo "== smoke: mixed-load scheduler (long prefill mid-decode, chunked) =="
# asserts decode keeps emitting while the long prompt chunk-prefills, the
# unchunked baseline stalls, stop-token requests finish with "stop", and
# the quick run stays under its wall-clock bound
python benchmarks/mixed_load.py --quick || exit 1

echo "== smoke: role-switch benchmark (workload shift, switching on/off) =="
# asserts >= 1 observed role switch with switching on and zero stranded
# requests in both runs
python benchmarks/role_switch.py --quick || exit 1

echo "== smoke: fault-recovery benchmark (death/replay/straggler rows) =="
# asserts zero stranded requests in every scenario and that the right
# counter moved (failovers for kv-migrate, replays for kv-replay)
python benchmarks/fault_recovery.py --quick || exit 1

echo "== smoke: kernel micro-bench (kernel-vs-ref + packed-runner rows) =="
python benchmarks/kernel_bench.py --quick || exit 1

echo "== smoke: live-gateway SLO attainment (open-loop HTTP traffic) =="
# sustained-QPS Poisson arrivals against the real engine behind the HTTP
# gateway; every request must complete, TTFT/TPOT measured at the HTTP
# boundary
timeout 600 python benchmarks/slo_attainment.py --gateway --quick || exit 1

echo "CI done (tier-1 exit: $tier1)"
exit "$tier1"
