"""HTTP serving front-to-back: two real EPD engines behind the
disaggregation-aware load balancer, fronted by the asyncio gateway —
then plain ``http.client`` traffic against it like any OpenAI endpoint:

  1. a streamed completion (SSE chunks printed as they arrive),
  2. a burst of completions balanced across both backends,
  3. /health and /metrics snapshots (per-backend pressure, LB counters,
     gateway admission stats).

    PYTHONPATH=src python examples/gateway_serve.py [--backends 2]
"""
import argparse
import http.client
import json

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EPDEngine, EngineConfig, GatewayServer,
                           LoadBalancer)


def _post(gw, payload, stream=False):
    conn = http.client.HTTPConnection(gw.host, gw.port, timeout=300)
    conn.request("POST", "/v1/chat/completions", body=json.dumps(payload),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    if stream:
        return resp, conn
    body = json.loads(resp.read())
    conn.close()
    return body


def _get(gw, path):
    conn = http.client.HTTPConnection(gw.host, gw.port, timeout=30)
    conn.request("GET", path)
    body = json.loads(conn.getresponse().read())
    conn.close()
    return body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b")
    ap.add_argument("--backends", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    engines = [EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=4, max_new_tokens=args.new_tokens))
        for _ in range(args.backends)]
    for e in engines:
        e.start()
    lb = LoadBalancer()
    for i, e in enumerate(engines):
        lb.add_backend(f"engine{i}", e)
    lb.start()
    gw = GatewayServer(lb).start()
    print(f"gateway up at {gw.url} "
          f"({args.backends} LB'd backends, arch={cfg.name})")

    # ---- 1. one streamed completion over SSE
    payload = {"messages": [{"role": "user",
                             "content": "stream me some tokens please"}],
               "max_tokens": args.new_tokens, "stream": True}
    resp, conn = _post(gw, payload, stream=True)
    print("SSE stream: ", end="", flush=True)
    buf = b""
    while True:
        chunk = resp.read(1)
        if not chunk:
            break
        buf += chunk
        while b"\n\n" in buf:
            event, buf = buf.split(b"\n\n", 1)
            data = event[len(b"data: "):].decode()
            if data == "[DONE]":
                print("[DONE]")
                continue
            delta = json.loads(data)["choices"][0]["delta"]
            if "content" in delta:
                print(delta["content"], end="", flush=True)
    conn.close()

    # ---- 2. a burst, balanced across backends
    for i in range(args.requests):
        body = _post(gw, {
            "messages": [{"role": "user", "content": f"burst request {i}"}],
            "max_tokens": args.new_tokens})
        t = body["timings"]
        print(f"  {body['id']}: tokens={body['choices'][0]['token_ids']} "
              f"ttft={t['ttft']*1e3:.1f}ms")

    # ---- 3. health + metrics
    health = _get(gw, "/health")
    for b in health["backends"]:
        print(f"  backend {b['name']}: healthy={b['healthy']} "
              f"queue={b['queue_depth']} "
              f"kv_free={b['kv_free_blocks']}/{b['kv_total_blocks']} "
              f"probe_ewma={b['ewma_ms'] and round(b['ewma_ms'], 2)}ms")
    metrics = _get(gw, "/metrics")
    print(f"  gateway: {metrics['gateway']}")
    print(f"  lb: {health['lb']}")

    gw.stop()
    lb.stop()
    for e in engines:
        e.stop()
    print("clean shutdown")


if __name__ == "__main__":
    main()
