"""Multi-instance cluster serving driver: boots the real-execution
ClusterEngine on a paper-notation spec and drives it through the
OpenAI-shaped frontend.

  1. requests fan out across instances (IRP shards may encode on
     DIFFERENT E instances; every prefill's KV migrates over ψ_PD to a
     decode instance — byte-exact, so greedy streams match EPDEngine),
  2. with --switch, a decode-heavy tail re-roles an idle E instance to
     D (paper §3.2.4: offload -> migrate -> onload) and the switch log
     is printed.

    PYTHONPATH=src python examples/cluster_serve.py \
        [--spec 2E1P1D] [--requests 8] [--switch]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ClusterConfig, ClusterEngine, EngineConfig
from repro.serving.api import build_chat_response, parse_chat_request


def _payload(cfg, rng, max_tokens, *, image_seed=None):
    content = [{"type": "text", "text": " ".join(
        f"word{rng.integers(1e6)}" for _ in range(12))}]
    if image_seed is not None:
        irng = np.random.default_rng(image_seed)
        M = 2 * cfg.modality.tokens_per_item
        emb = (irng.standard_normal((M, cfg.modality.enc_d_model))
               .astype(np.float32) * 0.1)
        content.append({"type": "image_embedding",
                        "embedding": emb.tolist()})
    return {"messages": [{"role": "user", "content": content}],
            "max_tokens": max_tokens}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b")
    ap.add_argument("--spec", default="2E1P1D",
                    help='cluster spec: "2E1P1D" EPD, "4EPD" vLLM '
                         'baseline, "3EP1D" DistServe')
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--switch", action="store_true",
                    help="enable dynamic role switching + decode-heavy "
                         "tail to trigger it")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    engine = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=2,
                     max_new_tokens=max(args.new_tokens, 24),
                     decode_batch=2),
        ClusterConfig(spec=args.spec, role_switch=args.switch,
                      monitor_interval=0.1, switch_cooldown=0.5))
    engine.start()
    print(f"cluster up: arch={cfg.name} spec={args.spec} "
          f"roles={engine.current_roles()} switch={args.switch}")
    rng = np.random.default_rng(0)

    handles = [engine.submit(parse_chat_request(cfg, _payload(
        cfg, rng, args.new_tokens, image_seed=i % 3)))
        for i in range(args.requests)]
    for h in handles:
        resp = build_chat_response(cfg, h.result(timeout=600))
        t = resp["timings"]
        print(f"  {resp['id']}: ttft={t['ttft']*1e3:8.1f}ms "
              f"mm_cache_hit={t['mm_cache_hit']!s:5} "
              f"tokens={resp['choices'][0]['token_ids']}")

    if args.switch:
        tail = [engine.submit(parse_chat_request(cfg, _payload(
            cfg, rng, 24))) for _ in range(3 * args.requests)]
        for h in tail:
            h.result(timeout=600)
        deadline = time.time() + 5
        while engine.stats["role_switches"] == 0 and time.time() < deadline:
            time.sleep(0.05)
    engine.stop()

    s = engine.stats
    print(f"stats: decode {s['decode_tokens']} tok over "
          f"{s['decode_steps']} batched steps, "
          f"pd_migrations={s['pd_migrations']}, "
          f"encode_shards={s['encode_shards']}, "
          f"mm_cache {s['mm_cache_hits']} hits / "
          f"{s['mm_cache_misses']} misses, "
          f"preemptions={s['preemptions']}")
    if args.switch:
        moves = ", ".join(f"i{i}:{o}->{n}"
                          for _, i, o, n in engine.switch_log) or "none"
        occ = {k: round(v, 1) for k, v in s["role_seconds"].items()}
        print(f"switching: {s['role_switches']} switches [{moves}] "
              f"final roles={engine.current_roles()} occupancy={occ}s")


if __name__ == "__main__":
    main()
