"""Quickstart: build any architecture by id, train a few steps, then
prefill + autoregressively decode — the full public API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch pixtral-12b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()       # CPU-friendly smoke scale
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.2f}M params ({cfg.family})")

    # --- train a few steps on the synthetic pipeline
    acfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps)
    opt = adamw_init(params)
    pipe = TokenPipeline(cfg, batch=2, seq_len=64)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch=batch), has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt, acfg)
        return params, opt, loss

    for i in range(args.steps):
        params, opt, loss = step(params, opt, pipe.batch_at(i))
        print(f"  step {i}: loss {float(loss):.4f}")

    # --- prefill + decode 8 tokens
    batch = pipe.batch_at(0)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    kw = {} if cfg.family == "ssm" else {"max_len": 64 + 16}
    logits, cache = model.prefill(params, batch=prompt, **kw)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    for _ in range(7):
        logits, cache = model.decode_step(params,
                                          batch={"token": tok, "cache": cache})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    print(f"  generated tokens: {out}")


if __name__ == "__main__":
    main()
