"""Dynamic role switching demo (paper §3.2.4 / Table 6): a workload that
shifts from short to long outputs mid-stream. The static 5E1P2D cluster
collapses on decode; with switching, E instances migrate to D
(offload -> migrate -> onload) and latency recovers.

    PYTHONPATH=src python examples/role_switch_demo.py
"""
from repro.configs import get_config
from repro.core import A100_80G
from repro.core.cluster import ClusterSpec, build_cluster, summarize, _clone
from repro.core.load_estimator import LoadEstimator
from repro.core.simulator import Simulator
from repro.data.workload import WorkloadSpec, poisson_requests


def main():
    cfg = get_config("minicpm-v-2.6")
    short = poisson_requests(cfg, WorkloadSpec(
        rate=3.0, n_requests=10, n_items=1, output_len=50))
    long_ = poisson_requests(cfg, WorkloadSpec(
        rate=3.0, n_requests=90, n_items=1, output_len=500, seed=1))
    for i, r in enumerate(long_):
        r.req_id = 100 + i
        r.arrival += short[-1].arrival
    reqs = short + long_

    print("workload: 10 requests x 50 output tokens, then 90 x 500 tokens")
    for switch in (False, True):
        spec = ClusterSpec("5E1P2D", role_switch=switch, decode_batch=4)
        sim = Simulator(cfg, A100_80G, build_cluster(spec, cfg, A100_80G),
                        role_switch=switch)
        out = sim.run([_clone(r) for r in reqs])
        s = summarize(out)
        label = "dynamic (switching ON)" if switch else "static 5E1P2D"
        print(f"  {label:24s} latency={s.latency_mean:6.2f}s "
              f"ttft={s.ttft_mean:5.2f}s tpot={s.tpot_mean:6.4f}s")
        if switch and sim.switch_log:
            moves = [f"{o}->{n}" for _, _, o, n in sim.switch_log[:8]]
            print(f"    switches: {', '.join(moves)}"
                  f"{' ...' if len(sim.switch_log) > 8 else ''}")

    # the load estimator's view of the shifted workload
    est = LoadEstimator(cfg, A100_80G)
    for r in reqs:
        est.observe(r, r.arrival)
    print(f"  load estimator end-state demand: "
          f"{ {k: round(v, 2) for k, v in est.stage_demand().items()} }")
    print(f"  suggested 8-instance split: {est.suggest_allocation(8)} "
          f"(paper reconfigures 5E1P2D -> 2E1P5D)")


if __name__ == "__main__":
    main()
