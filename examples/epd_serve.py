"""End-to-end EPD serving driver: boots the real-execution disaggregated
engine — E workers (IRP), P, D on live threads wired over ψ channels — and
drives it through the OpenAI-shaped frontend:

  1. one streamed completion (tokens printed as the D stage emits them),
  2. a batch of multimodal requests where half repeat an image, so the
     ψ_EP MMTokenCache serves the encoded tokens and the E stage is
     skipped on the repeats (paper §3.2.1),
  3. per-request chat.completion responses with ttft/tpot/mm_cache_hit.

    PYTHONPATH=src python examples/epd_serve.py [--requests 8] [--irp 2]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig
from repro.serving.api import build_chat_response, parse_chat_request


def _image_payload(cfg, rng, text_words, max_tokens, *, image_seed):
    """OpenAI-style multimodal payload with a seeded (repeatable) image."""
    irng = np.random.default_rng(image_seed)
    M = 2 * cfg.modality.tokens_per_item          # two image patch groups
    embedding = (irng.standard_normal((M, cfg.modality.enc_d_model))
                 .astype(np.float32) * 0.1)
    text = " ".join(f"word{rng.integers(1e6)}" for _ in range(text_words))
    return {"messages": [{"role": "user", "content": [
                {"type": "text", "text": text},
                {"type": "image_embedding", "embedding": embedding.tolist()},
            ]}],
            "max_tokens": max_tokens}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--irp", type=int, default=2)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--mode", choices=("paged", "dense"), default="paged",
                    help="decode stage: paged-batched shared pool (one "
                         "jitted step per iteration) or the dense "
                         "per-request baseline")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=args.irp, max_new_tokens=args.new_tokens,
        decode_batch=4, mode=args.mode))
    engine.start()
    print(f"EPD engine up: arch={cfg.name} E-workers(IRP)={args.irp} "
          f"decode={args.mode}")
    rng = np.random.default_rng(0)
    text_words = 2 * cfg.modality.tokens_per_item + 8

    # ---- 1. streaming: tokens arrive as the decode stage emits them
    handle = engine.submit(parse_chat_request(cfg, _image_payload(
        cfg, rng, text_words, args.new_tokens, image_seed=999)))
    print(f"stream req {handle.req_id}: ", end="", flush=True)
    for tok in handle.stream(timeout=600):
        print(tok, end=" ", flush=True)
    handle.result(timeout=600)
    print("(done)")

    # ---- 2. batch: half the requests repeat image 0 -> ψ_EP cache hits
    handles = []
    for i in range(args.requests):
        payload = _image_payload(cfg, rng, text_words, args.new_tokens,
                                 image_seed=0 if i % 2 == 0 else 100 + i)
        handles.append(engine.submit(parse_chat_request(cfg, payload)))
        time.sleep(rng.exponential(1.0 / args.rate))

    ttfts, hit_ttfts = [], []
    for h in handles:
        resp = build_chat_response(cfg, h.result(timeout=600))
        t = resp["timings"]
        (hit_ttfts if t["mm_cache_hit"] else ttfts).append(t["ttft"])
        print(f"  {resp['id']}: ttft={t['ttft']*1e3:8.1f}ms "
              f"tpot={t['tpot']*1e3:6.1f}ms "
              f"mm_cache_hit={t['mm_cache_hit']!s:5} "
              f"tokens={resp['choices'][0]['token_ids']}")
    engine.stop()

    s = engine.stats
    tok_s = s["decode_tokens"] / max(s["decode_time"], 1e-9)
    hit_ms = (f"{np.mean(hit_ttfts)*1e3:.1f}ms" if hit_ttfts
              else "n/a (no repeats)")
    print(f"mean ttft: first-seen={np.mean(ttfts)*1e3:.1f}ms  "
          f"mm-cache-hit={hit_ms}  "
          f"({s['mm_cache_hits']} hits / {s['mm_cache_misses']} misses, "
          f"{engine.encode_stage.shards_run} encode shards run)")
    print(f"decode[{args.mode}]: {tok_s:.1f} tok/s over "
          f"{s['decode_steps']} batched steps, "
          f"peak KV {s['peak_cache_bytes']/1024:.0f} KiB, "
          f"{s['preemptions']} preemptions")


if __name__ == "__main__":
    main()
