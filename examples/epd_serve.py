"""End-to-end EPD serving driver (deliverable b): boots the real-execution
disaggregated engine — E workers (IRP), P, D on live threads — and pushes a
batch of multimodal requests through encode -> ψ_EP -> prefill -> ψ_PD ->
decode, reporting per-request TTFT/TPOT.

    PYTHONPATH=src python examples/epd_serve.py [--requests 8] [--irp 2]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig, ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--irp", type=int, default=2)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--mode", choices=("paged", "dense"), default="paged",
                    help="decode stage: paged-batched shared pool (one "
                         "jitted step per iteration) or the dense "
                         "per-request baseline")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=args.irp, max_new_tokens=args.new_tokens,
        decode_batch=4, mode=args.mode))
    engine.start()
    print(f"EPD engine up: arch={cfg.name} E-workers(IRP)={args.irp} "
          f"decode={args.mode}")

    rng = np.random.default_rng(0)
    tpi = cfg.modality.tokens_per_item
    reqs = []
    for i in range(args.requests):
        M = 2 * tpi                             # two image patches
        reqs.append(ServeRequest(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab, 22).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1),
            mm_positions=np.arange(1, M + 1, dtype=np.int32),
            max_new_tokens=args.new_tokens))
        engine.submit(reqs[-1])
        time.sleep(rng.exponential(1.0 / args.rate))

    ttfts, tpots = [], []
    for r in reqs:
        out = engine.result(r.req_id, timeout=600)
        ttfts.append(out.ttft)
        tpots.append(out.tpot)
        print(f"  req {out.req_id}: ttft={out.ttft*1e3:8.1f}ms "
              f"tpot={out.tpot*1e3:6.1f}ms tokens={out.tokens}")
    engine.stop()
    s = engine.stats
    tok_s = s["decode_tokens"] / max(s["decode_time"], 1e-9)
    print(f"mean ttft={np.mean(ttfts)*1e3:.1f}ms  "
          f"mean tpot={np.mean(tpots)*1e3:.1f}ms  "
          f"({args.requests} requests, {args.irp} IRP workers)")
    print(f"decode[{args.mode}]: {tok_s:.1f} tok/s over "
          f"{s['decode_steps']} batched steps, "
          f"peak KV {s['peak_cache_bytes']/1024:.0f} KiB, "
          f"{s['preemptions']} preemptions")


if __name__ == "__main__":
    main()
