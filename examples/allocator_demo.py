"""Resource-allocation optimizer demo (paper §3.2.3 / Table 5): Bayesian
optimization over (instances-per-stage, batch sizes, IRP, scheduling) on
the cluster simulator. Recovers the paper's reported optimum (6E1P1D with
IRP for the MiniCPM workload — App. E.4).

    PYTHONPATH=src python examples/allocator_demo.py
"""
from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.allocator import goodput_objective, optimize_allocation
from repro.data.workload import WorkloadSpec, poisson_requests


def main():
    cfg = get_config("minicpm-v-2.6")
    slo = SLO(ttft=3.90, tpot=0.06)     # 6 images/request criteria
    rates = [0.25, 0.5, 1.0, 1.5, 2.0]

    def make_requests(rate):
        return poisson_requests(cfg, WorkloadSpec(
            rate=rate, n_requests=60, n_items=6, output_len=10, slo=slo))

    ev = goodput_objective(cfg, A100_80G, make_requests, slo, rates)
    print("optimizing 8-GPU allocation (GP-EI, ~20 simulator evals)...")
    res = optimize_allocation(ev, n_gpus=8, n_init=8, n_iter=12, seed=0)
    b = res.best
    print(f"best config: {b.spec().spec}  irp={b.irp} "
          f"batches=(E{b.batch_e}, P{b.batch_p}, D{b.batch_d}) "
          f"sched={b.queue_policy}/{b.assign_policy}")
    print(f"goodput: {res.best_score} req/s")
    print("paper (App E.4): 6 E / 1 P / 1 D workers, IRP enabled")
    top = sorted(res.history, key=lambda t: -t[1])[:5]
    for c, s in top:
        print(f"  {c.spec().spec:10s} irp={int(c.irp)} -> {s}")


if __name__ == "__main__":
    main()
