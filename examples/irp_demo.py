"""Intra-Request Parallelism demo (paper §3.2.2 / Table 4): the same
encode-heavy request stream served with 1 vs 4 E workers — real wall-clock
TTFT through the live engine, plus the simulator's cluster-scale view.

    PYTHONPATH=src python examples/irp_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import A100_80G
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import WorkloadSpec, poisson_requests
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig, ServeRequest


def live_engine_ttft(cfg, params, irp_workers: int, n_req: int = 4) -> float:
    engine = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=irp_workers, max_new_tokens=2, decode_batch=4))
    engine.start()
    rng = np.random.default_rng(0)
    tpi = cfg.modality.tokens_per_item
    M = 8 * tpi                                     # 8 patches per request
    reqs = []
    for i in range(n_req):
        reqs.append(ServeRequest(
            req_id=i, prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1),
            mm_positions=np.arange(1, M + 1, dtype=np.int32),
            max_new_tokens=2))
        engine.submit(reqs[-1])
    ttfts = [engine.result(r.req_id, timeout=600).ttft for r in reqs]
    engine.stop()
    time.sleep(0.1)
    return float(np.mean(ttfts))


def main():
    cfg = get_config("pixtral-12b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print("== live engine (reduced pixtral, 8 patches/request) ==")
    t1 = live_engine_ttft(cfg, params, irp_workers=1)
    t4 = live_engine_ttft(cfg, params, irp_workers=4)
    print(f"  IRP=1: ttft {t1*1e3:8.1f}ms")
    print(f"  IRP=4: ttft {t4*1e3:8.1f}ms   ({t1/t4:.2f}x faster)")

    print("== cluster simulator (paper Table 4 setting, MiniCPM-V 2.6) ==")
    mcfg = get_config("minicpm-v-2.6")
    for items in (2, 4, 8):
        reqs = poisson_requests(mcfg, WorkloadSpec(
            rate=0.25, n_requests=100, n_items=items, output_len=10))
        on = summarize(simulate(ClusterSpec("5E2P1D", irp=True), mcfg,
                                A100_80G, reqs))
        off = summarize(simulate(ClusterSpec("5E2P1D", irp=False), mcfg,
                                 A100_80G, reqs))
        print(f"  {items} img/req: ttft {on.ttft_mean:.2f}s with IRP, "
              f"{off.ttft_mean:.2f}s without ({off.ttft_mean/on.ttft_mean:.1f}x)"
              f"  [paper: {dict(((2,(0.92,1.46)),(4,(1.02,2.47)),(8,(1.74,4.27))))[items]}]")


if __name__ == "__main__":
    main()
