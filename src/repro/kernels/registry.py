"""Attention-backend registry: ONE dispatch seam for every attention site.

The serving stack reaches attention through four named entry points —
full-sequence prefill, prefix+chunk (chunked prefill), contiguous-cache
decode, and paged (block-table) decode. A backend binds all four:

  ``ref``     the pure-jnp substrate functions in ``models.attention``
              (today's default path everywhere off-TPU) plus the paged
              gather oracle — bit-identical to the historical engine.
  ``pallas``  the Pallas TPU kernels under ``repro.kernels`` — compiled
              on TPU, ``interpret=True`` elsewhere, so the same backend
              name works on every host. ``flash_prefill`` drives prefill
              (whole-prompt AND the prefix+chunk step of chunked
              prefill), ``paged_attn`` drives the token-packed runner and
              the batched decode step, ``decode_attn`` drives
              dense-cache decode.

Selection: ``EngineConfig.attn_backend`` if set, else the
``REPRO_ATTN_BACKEND`` environment variable, else the platform default
(``pallas`` on TPU, ``ref`` everywhere else — matching the historical
``force_ref = backend != "tpu"`` behavior). Unknown names fail fast with
the list of registered backends, so a typo'd env var cannot silently
fall back to the default.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (chunked_attention, decode_attention,
                                    prefix_chunk_attention)

ENV_VAR = "REPRO_ATTN_BACKEND"

__all__ = ["AttentionBackend", "ENV_VAR", "available_backends",
           "get_backend", "register_backend", "resolve_backend"]


@dataclass(frozen=True)
class AttentionBackend:
    """The four attention entry points the serving stack dispatches over.

    Layouts match the pure-jnp substrate (``models.attention``):
      prefill_attention(q (B,S,H,hd), k/v (B,S,K,hd), *, causal, window,
                        block_causal_skip) -> (B,S,H,hd)
      prefix_chunk_attention(q/k/v (B,C,·,hd), k_prev/v_prev (B,Pmax,K,hd),
                             prev_len ()) -> (B,C,H,hd)
      decode_attention(q (B,H,hd), caches (B,W,K,hd), length (B,))
      paged_attention(q (B,H,hd), pools (N,bs,K,hd),
                      block_tables (B,max_blocks), lengths (B,))
    """
    name: str
    prefill_attention: Callable
    prefix_chunk_attention: Callable
    decode_attention: Callable
    paged_attention: Callable


_REGISTRY: dict[str, AttentionBackend] = {}


def register_backend(backend: AttentionBackend) -> AttentionBackend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; "
            f"available: {', '.join(available_backends())}") from None


def resolve_backend(name: Optional[str] = None) -> AttentionBackend:
    """Backend by explicit name, else ``$REPRO_ATTN_BACKEND``, else the
    platform default (``pallas`` compiled on TPU, ``ref`` elsewhere)."""
    if name is None:
        name = os.environ.get(ENV_VAR) or None
    if name is None:
        name = "pallas" if jax.default_backend() == "tpu" else "ref"
    return get_backend(name)


# =================================================================== ref
def _paged_ref(q, k_pool, v_pool, block_tables, lengths):
    from repro.kernels.paged_attn.ref import paged_decode_attn_ref
    return paged_decode_attn_ref(q, k_pool, v_pool, block_tables, lengths)


register_backend(AttentionBackend(
    name="ref",
    prefill_attention=chunked_attention,
    prefix_chunk_attention=prefix_chunk_attention,
    decode_attention=decode_attention,
    paged_attention=_paged_ref,
))


# ================================================================ pallas
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flash_prefill_attention(q, k, v, *, causal=True, window=0,
                             block_causal_skip=False, **_):
    """``flash_prefill`` behind the substrate layout: (B,S,H,hd) in/out.

    ``block_causal_skip`` is subsumed — the kernel already skips kv
    blocks entirely above the causal diagonal."""
    from repro.kernels.flash_prefill.kernel import flash_prefill
    o = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal, window=window,
                      interpret=_interpret())
    return o.transpose(0, 2, 1, 3)


def _flash_prefix_chunk_attention(q, k, v, k_prev, v_prev, prev_len):
    """Chunked-prefill attention on the flash kernel.

    The chunk's queries sit at GLOBAL positions ``prev_len + i`` while the
    flash kernel's causal mask is index-aligned, so the chunk is staged
    into a static ``Pmax + C``-wide buffer: the prefix is compacted to the
    front (closing the ``prev_len..Pmax`` garbage gap), the chunk KV lands
    right after it, and the queries are scattered to start at index
    ``prev_len`` — index-causal == position-causal, one trace for every
    chunk of every request. Rows outside the real query span are garbage
    and sliced away."""
    from repro.kernels.flash_prefill.kernel import flash_prefill
    B, C, H, hd = q.shape
    Pmax = k_prev.shape[1]
    S = Pmax + C
    j = jnp.arange(S)
    # compacted source index: [prefix(:prev_len) | chunk | clamped tail]
    src = jnp.where(j < prev_len, j,
                    jnp.minimum(Pmax + (j - prev_len), S - 1))
    kc = jnp.take(jnp.concatenate([k_prev, k], axis=1), src, axis=1)
    vc = jnp.take(jnp.concatenate([v_prev, v], axis=1), src, axis=1)
    qs = jax.lax.dynamic_update_slice(
        jnp.zeros((B, S) + q.shape[2:], q.dtype), q, (0, prev_len, 0, 0))
    o = flash_prefill(qs.transpose(0, 2, 1, 3), kc.transpose(0, 2, 1, 3),
                      vc.transpose(0, 2, 1, 3), causal=True,
                      interpret=_interpret())
    o = o.transpose(0, 2, 1, 3)
    return jax.lax.dynamic_slice(o, (0, prev_len, 0, 0),
                                 (B, C) + o.shape[2:])


def _decode_attn_pallas(q, k_cache, v_cache, length):
    from repro.kernels.decode_attn.kernel import decode_attn
    return decode_attn(q, k_cache, v_cache, length, interpret=_interpret())


def _paged_attn_pallas(q, k_pool, v_pool, block_tables, lengths):
    from repro.kernels.paged_attn.kernel import paged_decode_attn
    return paged_decode_attn(q, k_pool, v_pool, block_tables, lengths,
                             interpret=_interpret())


register_backend(AttentionBackend(
    name="pallas",
    prefill_attention=_flash_prefill_attention,
    prefix_chunk_attention=_flash_prefix_chunk_attention,
    decode_attention=_decode_attn_pallas,
    paged_attention=_paged_attn_pallas,
))
