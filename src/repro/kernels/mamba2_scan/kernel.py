"""Mamba2 chunked-SSD Pallas TPU kernel.

Grid ``(B, H, n_chunks)`` — chunk dim 'arbitrary' (sequential) with the
per-head SSM state (P, N) carried in fp32 VMEM scratch. Each step does the
SSD chunk math on MXU-shaped matmuls: (Q,N)x(N,Q) scores, (Q,Q)x(Q,P)
intra-chunk output, (P,Q)x(Q,N) state update. Q=chunk, P=head_dim, N=d_state
(64..256 — all VMEM-friendly tiles).

Inputs: x (B,H,S,P); dt,a (B,H,S,1); Bm,Cm (B,S,N) (shared across heads).
Outputs: y (B,H,S,P) fp32; final_state (B,H,P,N) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)                       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                     # (Q, 1)
    a = a_ref[0, 0].astype(jnp.float32)                       # (Q, 1)
    bm = b_ref[0].astype(jnp.float32)                         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                         # (Q, N)
    state = state_scr[...]                                    # (P, N)

    cum = jnp.cumsum(a, axis=0)                               # (Q, 1)
    # inter-chunk: y_inter[q,p] = exp(cum_q) * C_q · state[p,:]
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)                          # (Q, P)
    # intra-chunk
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    diff = cum - cum.reshape(1, chunk)                        # (Q, Q) q-k
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = jnp.where(q_idx >= k_idx, diff, -jnp.inf)
    m = scores * jnp.exp(diff) * dt.reshape(1, chunk)
    y_intra = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)
    # state update: state = exp(cum_Q)*state + x^T @ (B * tail * dt)
    tail = jnp.exp(cum[chunk - 1:chunk] - cum)                # (Q, 1)
    contrib = jax.lax.dot_general(x, bm * (tail * dt),
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[chunk - 1, 0]) + contrib

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        st_ref[0, 0] = state_scr[...]


def mamba2_ssd(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
               bm: jnp.ndarray, cm: jnp.ndarray, *, chunk: int = 256,
               interpret: bool = True):
    """x (B,S,H,P); dt,a (B,S,H); bm,cm (B,S,N).
    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3)                               # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)[..., None]                     # (B,H,S,1)
    at = a.transpose(0, 2, 1)[..., None]

    kern = functools.partial(_ssd_kernel, chunk=Q, n_chunks=nc)
    y, st = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, at, bm, cm)
    return y.transpose(0, 2, 1, 3), st
