"""jit'd public wrapper for the Mamba2 SSD scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba2_scan.kernel import mamba2_ssd
from repro.kernels.mamba2_scan.ref import mamba2_ssd_ref


@partial(jax.jit, static_argnames=("chunk", "force_ref"))
def mamba2_ssd_op(x, dt, a, bm, cm, *, chunk: int = 256,
                  force_ref: bool = False):
    if force_ref:
        return mamba2_ssd_ref(x, dt, a, bm, cm, chunk=chunk)
    return mamba2_ssd(x, dt, a, bm, cm, chunk=chunk,
                      interpret=jax.default_backend() != "tpu")
