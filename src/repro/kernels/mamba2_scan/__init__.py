from repro.kernels.mamba2_scan.kernel import mamba2_ssd
from repro.kernels.mamba2_scan.ops import mamba2_ssd_op
from repro.kernels.mamba2_scan.ref import mamba2_ssd_ref

__all__ = ["mamba2_ssd", "mamba2_ssd_op", "mamba2_ssd_ref"]
