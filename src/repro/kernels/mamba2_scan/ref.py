"""Pure-jnp oracle for the Mamba2 SSD kernel — the model substrate's own
chunked scan."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def mamba2_ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                   bm: jnp.ndarray, cm: jnp.ndarray, *, chunk: int = 256):
    """Same contract as kernel.mamba2_ssd."""
    return ssd_chunked(x, dt, a, bm, cm, chunk)
