"""jit'd public wrapper for decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref


@partial(jax.jit, static_argnames=("force_ref",))
def decode_attention_op(q: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray, length: jnp.ndarray, *,
                        force_ref: bool = False) -> jnp.ndarray:
    if force_ref:
        return decode_attn_ref(q, k_cache, v_cache, length)
    return decode_attn(q, k_cache, v_cache, length,
                       interpret=jax.default_backend() != "tpu")
