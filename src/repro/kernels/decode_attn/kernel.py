"""GQA decode attention (flash-decoding style) Pallas TPU kernel.

One new query token per sequence attends over a (ring-buffer) KV cache.
Grid ``(B, K, n_w_blocks)`` — the cache-window dim is 'arbitrary'
(sequential) with fp32 online-softmax scratch carried across steps, so the
cache streams HBM->VMEM once. The G = H//K query rows of a kv group ride
along the sublane dim of one tile (padded to 8 on real TPUs — the MXU/VPU
tile is (8,128); interpret mode is shape-agnostic).

Layouts: q (B, K, G, hd); k/v cache (B, K, W, hd); length (B, 1) int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, w_block: int, n_w: int, sm_scale: float):
    wi = pl.program_id(2)

    @pl.when(wi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]
    # skip blocks entirely beyond the valid region
    @pl.when(wi * w_block < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (wb, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (G, wb)
        k_pos = wi * w_block + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(wi == n_w - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                length: jnp.ndarray, *, w_block: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """q (B,H,hd); caches (B,W,K,hd); length (B,) -> out (B,H,hd)."""
    B, W, K, hd = k_cache.shape
    H = q.shape[1]
    G = H // K
    sm_scale = 1.0 / math.sqrt(hd)

    w_block = min(w_block, W)
    nw = -(-W // w_block)
    pw = nw * w_block - W
    kc = k_cache.transpose(0, 2, 1, 3)                         # (B,K,W,hd)
    vc = v_cache.transpose(0, 2, 1, 3)
    if pw:
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pw), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pw), (0, 0)))
    qg = q.reshape(B, K, G, hd)
    len2d = jnp.minimum(length, W).astype(jnp.int32).reshape(B, 1)

    kern = functools.partial(_decode_kernel, w_block=w_block, n_w=nw,
                             sm_scale=sm_scale)
    out = pl.pallas_call(
        kern,
        grid=(B, K, nw),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, k, wi: (b, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, k, wi: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, w_block, hd), lambda b, k, wi: (b, k, wi, 0)),
            pl.BlockSpec((1, 1, w_block, hd), lambda b, k, wi: (b, k, wi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, k, wi: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(len2d, qg, kc, vc)
    return out.reshape(B, H, hd)
