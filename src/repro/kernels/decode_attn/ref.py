"""Pure-jnp oracle for the decode-attention kernel (the model substrate's
own decode path)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention


def decode_attn_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, length: jnp.ndarray) -> jnp.ndarray:
    """q (B,H,hd); caches (B,W,K,hd); length (B,) -> (B,H,hd)."""
    return decode_attention(q, k_cache, v_cache, length)
