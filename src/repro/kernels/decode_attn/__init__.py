from repro.kernels.decode_attn.kernel import decode_attn
from repro.kernels.decode_attn.ops import decode_attention_op
from repro.kernels.decode_attn.ref import decode_attn_ref

__all__ = ["decode_attn", "decode_attention_op", "decode_attn_ref"]
