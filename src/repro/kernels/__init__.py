"""Pallas TPU kernels for the serving hot-spots.

Each kernel subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd wrapper; interpret-mode off-TPU), and ``ref.py``
(pure-jnp oracle — the model substrate's own implementation, so kernels and
models are validated against identical semantics).

- flash_prefill: causal/full GQA flash attention (P stage, encoder)
- decode_attn:   flash-decoding over (ring) KV caches (D stage)
- mamba2_scan:   chunked SSD scan (zamba2 backbone)
- rwkv6_scan:    chunked data-dependent-decay WKV (rwkv6)
- paged_attn:    decode attention over vLLM-style block-table paged KV pools

``registry`` binds the kernels (and their pure-jnp oracles) into named
attention backends — ``ref`` / ``pallas`` — selected per engine via
``EngineConfig.attn_backend`` or ``$REPRO_ATTN_BACKEND``.
"""
from repro.kernels.decode_attn import decode_attention_op
from repro.kernels.paged_attn import paged_decode_attention_op
from repro.kernels.flash_prefill import flash_attention
from repro.kernels.mamba2_scan import mamba2_ssd_op
from repro.kernels.registry import (AttentionBackend, available_backends,
                                    get_backend, register_backend,
                                    resolve_backend)
from repro.kernels.rwkv6_scan import rwkv6_wkv_op

__all__ = ["AttentionBackend", "available_backends", "decode_attention_op",
           "flash_attention", "get_backend", "mamba2_ssd_op",
           "paged_decode_attention_op", "register_backend",
           "resolve_backend", "rwkv6_wkv_op"]
