"""Pallas TPU kernels for the serving hot-spots.

Each kernel subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd wrapper; interpret-mode off-TPU), and ``ref.py``
(pure-jnp oracle — the model substrate's own implementation, so kernels and
models are validated against identical semantics).

- flash_prefill: causal/full GQA flash attention (P stage, encoder)
- decode_attn:   flash-decoding over (ring) KV caches (D stage)
- mamba2_scan:   chunked SSD scan (zamba2 backbone)
- rwkv6_scan:    chunked data-dependent-decay WKV (rwkv6)
- paged_attn:    decode attention over vLLM-style block-table paged KV pools
"""
from repro.kernels.decode_attn import decode_attention_op
from repro.kernels.paged_attn import paged_decode_attention_op
from repro.kernels.flash_prefill import flash_attention
from repro.kernels.mamba2_scan import mamba2_ssd_op
from repro.kernels.rwkv6_scan import rwkv6_wkv_op

__all__ = ["decode_attention_op", "flash_attention", "mamba2_ssd_op",
           "paged_decode_attention_op", "rwkv6_wkv_op"]
