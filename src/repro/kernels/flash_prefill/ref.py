"""Pure-jnp oracle for the flash prefill kernel.

Delegates to the model substrate's chunked attention (layout-adapted), so
the kernel and the model path are validated against the same semantics.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import chunked_attention


def flash_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B,H,Sq,hd); k/v (B,K,Sk,hd) -> (B,H,Sq,hd)."""
    o = chunked_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal,
                          window=window)
    return o.transpose(0, 2, 1, 3)
