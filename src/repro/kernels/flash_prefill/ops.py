"""jit'd public wrapper for flash prefill attention.

On TPU backends the Pallas kernel runs compiled; elsewhere it runs in
``interpret=True`` mode (or falls back to the jnp oracle when
``force_ref``), so the same call site works everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "force_ref"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    force_ref: bool = False) -> jnp.ndarray:
    """q (B,H,Sq,hd); k/v (B,K,Sk,hd) -> (B,H,Sq,hd)."""
    if force_ref:
        return flash_prefill_ref(q, k, v, causal=causal, window=window)
    return flash_prefill(q, k, v, causal=causal, window=window,
                         interpret=not _on_tpu())
