"""Flash attention (prefill) Pallas TPU kernel.

Canonical TPU flash shape: 3-D grid ``(batch*q_heads, n_q_blocks,
n_kv_blocks)`` with the kv dim 'arbitrary' (sequential) so fp32
running-max/denominator/accumulator scratch in VMEM persists across kv
steps. Tiles are MXU-aligned (q_block x head_dim and kv_block x head_dim,
128-multiples for full-size heads). GQA is handled by mapping q head
``h`` to kv head ``h // G`` in the kv BlockSpec index_map — the repeated
KV is never materialized in HBM.

Kernel layouts: q (B, H, Sq, hd); k/v (B, K, Sk, hd); out (B, H, Sq, hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, sm_scale: float,
                  q_block: int, kv_block: int, n_kv: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)

    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (qb, hd)
        k = k_ref[0].astype(jnp.float32)                     # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        mask = k_pos < sk
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # whole kv block above the diagonal contributes nothing: skip
        pl.when((ki * kv_block) <= (qi * q_block + q_block - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  q_block: int = 128, kv_block: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """q (B,H,Sq,hd); k/v (B,K,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    _, K, Sk, _ = k.shape
    G = H // K
    sm_scale = 1.0 / math.sqrt(hd)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    nq = -(-Sq // q_block)
    nk = -(-Sk // kv_block)
    pq, pk = nq * q_block - Sq, nk * kv_block - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    grid = (B * H, nq, nk)
    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, sm_scale=sm_scale,
        q_block=q_block, kv_block=kv_block, n_kv=nk, sk=Sk)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd),
                         lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, hd),
                         lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, kv_block, hd),
                         lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * q_block, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),   # running max
            pltpu.VMEM((q_block, 1), jnp.float32),   # denominator
            pltpu.VMEM((q_block, hd), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(q.reshape(B * H, nq * q_block, hd),
      k.reshape(B * K, nk * kv_block, hd),
      v.reshape(B * K, nk * kv_block, hd))
    return out.reshape(B, H, nq * q_block, hd)[:, :, :Sq]
