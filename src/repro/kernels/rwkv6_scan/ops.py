"""jit'd public wrapper for the RWKV6 WKV scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
from repro.kernels.rwkv6_scan.ref import rwkv6_wkv_ref


@partial(jax.jit, static_argnames=("chunk", "force_ref"))
def rwkv6_wkv_op(r, k, v, w, u, *, chunk: int = 64, force_ref: bool = False):
    if force_ref:
        return rwkv6_wkv_ref(r, k, v, w, u, chunk=chunk)
    return rwkv6_wkv(r, k, v, w, u, chunk=chunk,
                     interpret=jax.default_backend() != "tpu")
