"""Pure-jnp oracle for the RWKV6 WKV kernel — the model substrate's own
sequential scan."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.rwkv6 import wkv_scan


def rwkv6_wkv_ref(r, k, v, w, u, *, chunk: int = 64):
    """Same contract as kernel.rwkv6_wkv (chunk is ignored — exact scan)."""
    B, S, H, P = r.shape
    state0 = jnp.zeros((B, H, P, P), jnp.float32)
    y, st = wkv_scan(r, k, v, w, u, state0)
    return y, st
