"""RWKV6 (Finch) chunked-WKV Pallas TPU kernel.

Recurrence (per head, state S in R^{PxP}, data-dependent per-channel decay
w_t in (0,1)):   y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
                 S_t = diag(w_t) S_{t-1} + k_t v_t^T

Chunked formulation in log-decay space (lw = log w, cumulative ``cum``):
  y_inter_t = (r_t ⊙ exp(cum_{t-1})) · S_chunk_start
  y_intra_t = Σ_{j<t} [Σ_p r_tp k_jp exp(cum_{t-1,p} − cum_{j,p})] v_j
              + (r_t ⊙ u) · k_t · v_t                (current-token bonus)
  S_new     = diag(exp(cum_Q)) S + (k ⊙ exp(cum_Q − cum))^T V

All exponents are differences of later-minus-earlier cumulative decays, so
every factor is ≤ 1 — no overflow for arbitrarily strong decay (this is why
the kernel does NOT use the naive k·exp(−cum) factorization).

Grid ``(B, H, n_chunks)``, chunk dim sequential, state in fp32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, st_ref, state_scr,
                 *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)                        # (Q, P)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)                      # (Q, P) log w
    u = u_ref[0].astype(jnp.float32)                           # (1? P,) -> (P,)
    state = state_scr[...]                                     # (P, P) k x v

    cum = jnp.cumsum(lw, axis=0)                               # (Q, P)
    cpre = cum - lw                                            # exclusive

    # inter-chunk: (r ⊙ exp(cpre)) @ state
    y_inter = jax.lax.dot_general(r * jnp.exp(cpre), state,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk scores[t,j] = Σ_p r_tp k_jp exp(cpre_t,p - cum_j,p), j<t
    diff = cpre[:, None, :] - cum[None, :, :]                  # (Q, Q, P)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (t_idx > j_idx)[:, :, None]
    prod = r[:, None, :] * k[None, :, :] * jnp.exp(
        jnp.where(strict, diff, -jnp.inf))                     # (Q, Q, P)
    scores = prod.sum(axis=2)                                  # (Q, Q)
    # current-token bonus on the diagonal
    bonus = (r * u[None, :] * k).sum(axis=1)                   # (Q,)
    scores = scores + jnp.where(
        t_idx == j_idx, bonus[:, None], 0.0)
    y_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update
    tail = jnp.exp(cum[chunk - 1:chunk, :] - cum)              # (Q, P)
    knew = jax.lax.dot_general(k * tail, v, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[chunk - 1])[:, None] + knew

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        st_ref[0, 0] = state_scr[...]


def rwkv6_wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
              u: jnp.ndarray, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w (B,S,H,P) with w = decay in (0,1); u (H,P).
    Returns (y (B,S,H,P) fp32, final state (B,H,P,P) fp32)."""
    B, S, H, P = r.shape
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    tr = lambda t: t.transpose(0, 2, 1, 3)                     # (B,H,S,P)
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))

    kern = functools.partial(_rwkv_kernel, chunk=Q, n_chunks=nc)
    y, st = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, P), lambda b, h, ci: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, P, P), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(lw), u)
    return y.transpose(0, 2, 1, 3), st
