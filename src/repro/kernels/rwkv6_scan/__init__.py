from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
from repro.kernels.rwkv6_scan.ops import rwkv6_wkv_op
from repro.kernels.rwkv6_scan.ref import rwkv6_wkv_ref

__all__ = ["rwkv6_wkv", "rwkv6_wkv_op", "rwkv6_wkv_ref"]
