"""Pure-jnp oracle for paged decode attention: gather the sequence's blocks
into a contiguous cache, then run the substrate's decode attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention


def paged_decode_attn_ref(q, k_pool, v_pool, block_tables, lengths):
    """Same contract as kernel.paged_decode_attn."""
    B = q.shape[0]
    N, bs, K, hd = k_pool.shape
    # (B, max_blocks, bs, K, hd) -> (B, W, K, hd)
    kc = k_pool[block_tables].reshape(B, -1, K, hd)
    vc = v_pool[block_tables].reshape(B, -1, K, hd)
    return decode_attention(q, kc, vc, lengths)
