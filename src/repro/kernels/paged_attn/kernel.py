"""Paged decode attention Pallas TPU kernel (vLLM-style block tables).

The paper's orchestration layer manages "paged multimodal caches" with
custom kernels (App. E); this is the TPU-native equivalent for the KV side:
the cache lives as a global pool of fixed-size blocks ``(N_blocks, bs, K,
hd)`` and each sequence owns a list of block ids (its block table). One new
query token attends over the sequence's blocks WITHOUT materializing a
contiguous cache.

Grid ``(B, K, max_blocks)`` — the block dim is 'arbitrary' (sequential) with
online-softmax scratch carried across steps. The per-sequence block table
rides in scalar-prefetch memory (SMEM) so the kv BlockSpec index_map can
look up the physical block id per grid step: HBM->VMEM streams exactly the
blocks the sequence owns (TPU's answer to the GPU gather — the index_map IS
the page table walk).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, block_size: int, n_blocks: int,
                  sm_scale: float):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = pl.program_id(0)
    length = len_ref[b]

    @pl.when(bi * block_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (G, bs)
        pos = bi * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(bi == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attn(q: jnp.ndarray, k_pool: jnp.ndarray,
                      v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                      lengths: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """q (B,H,hd); pools (N_blocks, bs, K, hd); block_tables (B, max_blocks)
    int32 physical block ids; lengths (B,). Returns (B, H, hd)."""
    B, H, hd = q.shape
    N, bs, K, _ = k_pool.shape
    G = H // K
    max_blocks = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd)
    kp = k_pool.transpose(0, 2, 1, 3)                         # (N, K, bs, hd)
    vp = v_pool.transpose(0, 2, 1, 3)

    kern = functools.partial(_paged_kernel, block_size=bs,
                             n_blocks=max_blocks, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # block_tables, lengths
        grid=(B, K, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, bi, tables, lens: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b, k, bi, tables, lens: (tables[b, bi], k, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b, k, bi, tables, lens: (tables[b, bi], k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, bi, tables, lens: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qg, kp, vp)
    return out.reshape(B, H, hd)
