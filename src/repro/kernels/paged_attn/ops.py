"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attn.kernel import paged_decode_attn
from repro.kernels.paged_attn.ref import paged_decode_attn_ref


@partial(jax.jit, static_argnames=("force_ref",))
def paged_decode_attention_op(q, k_pool, v_pool, block_tables, lengths, *,
                              force_ref: bool = False):
    if force_ref:
        return paged_decode_attn_ref(q, k_pool, v_pool, block_tables, lengths)
    return paged_decode_attn(q, k_pool, v_pool, block_tables, lengths,
                             interpret=jax.default_backend() != "tpu")
