from repro.kernels.paged_attn.kernel import paged_decode_attn
from repro.kernels.paged_attn.ops import paged_decode_attention_op
from repro.kernels.paged_attn.ref import paged_decode_attn_ref

__all__ = ["paged_decode_attn", "paged_decode_attention_op",
           "paged_decode_attn_ref"]
