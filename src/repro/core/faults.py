"""Fault injection plans for the cluster serving layer.

Production clusters limp before they die (limplock): a degraded node
first runs slow, then stalls, then disappears. A :class:`FaultPlan`
scripts exactly that — per-instance latency multipliers, hard stalls,
and deaths at scheduled times — and is consumed by BOTH execution
substrates:

  * ``core.simulator.Simulator(faults=...)`` scales analytical service
    times, parks stalled instances, and re-homes a dead instance's
    queued jobs and decode residents (degraded-node modeling);
  * the real ``serving.cluster.ClusterEngine(faults=...)`` checks the
    plan at the top of every instance executor loop (an injectable
    shim): slowdowns sleep proportionally to real step time, stalls
    park the executor, deaths make the executor thread exit so the
    supervisor's failover sweep re-homes the residents.

Instances are addressed by their position in the cluster spec order
(``iid`` 0..N-1) — identical between ``Simulator.instances`` and
``ClusterEngine.instances``, so one plan drives the sim-vs-real
structural cross-validation. Plans are immutable after construction and
therefore safely readable from any thread without locks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["Slowdown", "Stall", "Death", "FaultPlan"]


@dataclass(frozen=True)
class Slowdown:
    """Instance ``iid`` runs ``factor``x slower on [start, start+duration)."""
    iid: int
    start: float
    factor: float
    duration: float = math.inf

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass(frozen=True)
class Stall:
    """Instance ``iid`` makes no progress at all on [start, start+duration)
    — the limplock middle ground between slow and dead (e.g. a GC pause,
    a network partition that heals)."""
    iid: int
    start: float
    duration: float

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass(frozen=True)
class Death:
    """Instance ``iid`` dies at ``at`` and never comes back.

    ``kv_reachable`` selects the failover mode for decode residents:
    True models a process/accelerator failure whose HBM is still
    addressable (or checkpointed KV) — residents migrate byte-exact via
    ψ_PD extract/inject; False models the machine vanishing — residents
    replay from the prompt (preemption-replay)."""
    iid: int
    at: float
    kv_reachable: bool = True


@dataclass(frozen=True)
class FaultPlan:
    """An immutable schedule of injected faults, queried by (iid, now)."""
    slowdowns: tuple = ()
    stalls: tuple = ()
    deaths: tuple = ()

    def __post_init__(self):
        # accept lists at construction; store tuples (immutability)
        object.__setattr__(self, "slowdowns", tuple(self.slowdowns))
        object.__setattr__(self, "stalls", tuple(self.stalls))
        object.__setattr__(self, "deaths", tuple(self.deaths))

    # ------------------------------------------------------------ queries
    def multiplier(self, iid: int, now: float) -> float:
        """Combined service-time multiplier active on ``iid`` at ``now``."""
        m = 1.0
        for s in self.slowdowns:
            if s.iid == iid and s.start <= now < s.end:
                m *= s.factor
        return m

    def stall_until(self, iid: int, now: float) -> float:
        """End of any stall covering ``now`` (== ``now`` when none)."""
        end = now
        for s in self.stalls:
            if s.iid == iid and s.start <= now < s.end:
                end = max(end, s.end)
        return end

    def death_for(self, iid: int) -> Optional[Death]:
        for d in self.deaths:
            if d.iid == iid:
                return d
        return None

    def dead(self, iid: int, now: float) -> bool:
        d = self.death_for(iid)
        return d is not None and now >= d.at

    @property
    def horizon(self) -> float:
        """Latest scheduled fault onset (benchmarks size runs past it)."""
        times = ([s.start for s in self.slowdowns]
                 + [s.start for s in self.stalls]
                 + [d.at for d in self.deaths])
        return max(times, default=0.0)
