# The paper's primary contribution: EPD disaggregation.
# - request.py        request lifecycle + SLO metrics
# - block_manager.py  MM / KV paged caches (paper §3.2.1)
# - instance.py       E/P/D stage instances (+ aggregated baselines)
# - scheduler.py      assignment + queue-ordering policies (App. D)
# - simulator.py      discrete-event cluster sim: IRP, migrations, switching
# - costmodel.py      roofline stage costs, A100/910B3/TPUv5e profiles
# - cluster.py        "5E2P1D"-style specs, metrics, goodput
# - allocator.py      black-box (GP-EI) resource allocation (§3.2.3)
# - faults.py         injected fault plans (slowdowns / stalls / deaths)
from repro.core.block_manager import (BlockManager, KVBlockManager,
                                      MMBlockManager, OutOfBlocks)
from repro.core.cluster import ClusterSpec, Summary, goodput, simulate, summarize
from repro.core.costmodel import (A100_80G, NPU_910B3, PROFILES, TPU_V5E,
                                  HardwareProfile)
from repro.core.faults import Death, FaultPlan, Slowdown, Stall
from repro.core.instance import Instance
from repro.core.request import SLO, Request
from repro.core.simulator import Simulator

__all__ = [
    "A100_80G", "NPU_910B3", "PROFILES", "TPU_V5E", "BlockManager",
    "ClusterSpec", "Death", "FaultPlan", "HardwareProfile", "Instance",
    "KVBlockManager", "MMBlockManager", "OutOfBlocks", "Request", "SLO",
    "Simulator", "Slowdown", "Stall", "Summary", "goodput", "simulate",
    "summarize",
]
