"""Load estimation (paper App. E: "a load estimation module ensures
efficient GPU allocation across these phases, adapting to changing workload
demands in real time").

Tracks an exponentially-weighted profile of the arriving workload (rate,
patches/request, prefill tokens, output length) and converts it into
per-stage demand in device-seconds/second — the signal the role-switching
monitor and the allocator consume.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.request import Request


@dataclass
class LoadEstimator:
    cfg: ArchConfig
    hw: cm.HardwareProfile
    halflife_s: float = 30.0
    # EWMA state
    _rate: float = 0.0
    _patches: float = 0.0
    _prefill_tokens: float = 0.0
    _output_len: float = 0.0
    _last_t: float = -1.0
    _n: int = 0
    # the real engine observes from concurrent submit() threads while the
    # role-switch monitor reads demand; the simulator is single-threaded
    # and pays only an uncontended acquire
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def observe(self, req: Request, now: float) -> None:
        self.observe_raw(now, n_patches=req.n_patches,
                         prefill_tokens=req.prefill_tokens,
                         output_len=req.output_len)

    def observe_raw(self, now: float, *, n_patches: int,
                    prefill_tokens: int, output_len: int) -> None:
        """Workload observation without a ``core.request.Request`` — the
        serving engines feed arrivals straight from ``ServeRequest``
        fields (thread-safe)."""
        with self._lock:
            if self._last_t >= 0:
                dt = max(now - self._last_t, 1e-6)
                inst_rate = 1.0 / dt
                a = self._alpha(dt)
                self._rate = (1 - a) * self._rate + a * inst_rate
            self._last_t = now
            a = 0.2 if self._n >= 5 else 1.0 / (self._n + 1)
            self._patches = (1 - a) * self._patches + a * n_patches
            self._prefill_tokens = ((1 - a) * self._prefill_tokens
                                    + a * prefill_tokens)
            self._output_len = (1 - a) * self._output_len + a * output_len
            self._n += 1

    def _alpha(self, dt: float) -> float:
        return 1.0 - 0.5 ** (dt / self.halflife_s)

    # ------------------------------------------------------------- demand
    def stage_demand(self) -> dict[str, float]:
        """Device-seconds of work arriving per second, per stage."""
        with self._lock:
            if self._n == 0:
                return {"E": 0.0, "P": 0.0, "D": 0.0}
            r, patches = self._rate, self._patches
            prefill_tokens, output_len = self._prefill_tokens, self._output_len
        t_e = cm.encode_time(self.cfg, self.hw, max(1, int(patches))) \
            if self.cfg.modality and patches >= 0.5 else 0.0
        t_p = cm.prefill_time(self.cfg, self.hw,
                              max(1, int(prefill_tokens)))
        t_d = output_len * cm.decode_step_time(
            self.cfg, self.hw, int(prefill_tokens + output_len))
        return {"E": r * t_e, "P": r * t_p, "D": r * t_d}

    def utilization(self, counts: dict[str, int]) -> dict[str, float]:
        """Per-stage demand divided by serving instances: device-sec/sec
        of arriving work per device. > 1.0 means the stage is underwater;
        ``inf`` flags demand against a stage with zero instances."""
        demand = self.stage_demand()
        out: dict[str, float] = {}
        for s in "EPD":
            n = counts.get(s, 0)
            d = demand.get(s, 0.0)
            out[s] = 0.0 if d <= 0.0 else (d / n if n else float("inf"))
        return out

    def suggest_scale(self, counts: dict[str, int], *, up: float = 0.9,
                      down: float = 0.3):
        """Elastic-scaling hint (ElasticMM-style): ``("up", letter)`` for
        the most underwater stage above the ``up`` watermark, else
        ``("down", letter)`` for the idlest multi-instance stage below the
        ``down`` watermark, else ``None``. The caller owns cooldowns and
        min/max fleet bounds."""
        util = self.utilization(counts)
        served = [s for s in "EPD" if counts.get(s, 0) > 0]
        if not served:
            return None
        hot = max(served, key=lambda s: util[s])
        if util[hot] >= up:
            return ("up", hot)
        shrinkable = [s for s in served if counts[s] > 1]
        if shrinkable:
            cold = min(shrinkable, key=lambda s: util[s])
            if util[cold] < down:
                return ("down", cold)
        return None

    def suggest_allocation(self, n_instances: int) -> dict[str, int]:
        """Proportional-demand instance split (floor 1 per needed stage)."""
        demand = self.stage_demand()
        stages = [s for s, d in demand.items() if d > 0]
        if not stages:
            return {"E": 0, "P": max(1, n_instances - 1), "D": 1}
        total = sum(demand[s] for s in stages)
        out = {s: 0 for s in "EPD"}
        left = n_instances
        for s in stages:
            out[s] = max(1, round(n_instances * demand[s] / total))
        # normalize to exactly n_instances
        while sum(out.values()) > n_instances:
            hot = max((s for s in stages if out[s] > 1),
                      key=lambda s: out[s] / max(demand[s], 1e-9),
                      default=None)
            if hot is None:
                break
            out[hot] -= 1
        while sum(out.values()) < n_instances:
            hot = max(stages, key=lambda s: demand[s] / max(out[s], 1))
            out[hot] += 1
        return out
