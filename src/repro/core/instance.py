"""Stage instances.

An instance = a group of ``chips`` accelerators running ONE pipeline role:
  'E'   multimodal encoder            (MM cache, encoder weights only)
  'P'   prefill                       (LLM weights, MM + KV cache)
  'D'   decode                        (LLM weights, KV cache)
  'EP'  aggregated encode+prefill     (DistServe baseline)
  'EPD' fully aggregated              (vLLM baseline)

Jobs of every stage the role serves go through ONE serialized executor —
which is precisely how the aggregated baselines exhibit the encode/prefill
interference of paper Fig. 1, and how EPD avoids it.

On a real TPU deployment an instance is a submesh; here the same object
carries the simulator's queue/cache state. Dynamic role switching
(paper §3.2.4) swaps ``role`` and block managers in-place.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.block_manager import KVBlockManager, MMBlockManager

E_ROLES = {"E", "EP", "EPD"}
P_ROLES = {"P", "EP", "EPD"}
D_ROLES = {"D", "EPD"}

# paper §3.2.4: role switch < 0.7 s when E involved (model + cache swap),
# much shorter between P and D (LLM + KV cache reused).
SWITCH_LATENCY_E = 0.65
SWITCH_LATENCY_PD = 0.15


@dataclass
class EncodeJob:
    req_id: int
    n_patches: int              # patches in THIS shard (IRP may split)
    shard_id: int = 0
    n_shards: int = 1


@dataclass
class PrefillJob:
    req_id: int
    seq_len: int                # prompt + multimodal tokens


@dataclass
class DecodeSlot:
    req_id: int
    context: int                # current context length
    remaining: int              # tokens still to emit


class Instance:
    _ids = itertools.count()

    def __init__(self, role: str, chips: int, cfg: ArchConfig,
                 hw: cm.HardwareProfile, *, max_batch: int = 8,
                 decode_batch: int = 128, kv_frac: float = 0.8,
                 mm_blocks: int = 3000, block_size: int = 16):
        self.id = next(Instance._ids)
        self.role = role
        self.chips = chips
        self.cfg = cfg
        self.hw = hw
        self.max_batch = max_batch
        self.decode_batch = decode_batch
        self.kv_frac = kv_frac
        self.block_size = block_size
        self.mm_blocks = mm_blocks

        self.queue: list = []            # Encode/Prefill jobs
        self.decode_slots: list[DecodeSlot] = []
        self.busy_until: float = 0.0
        self.accepting: bool = True
        self.alive: bool = True           # cleared by injected fault deaths
        self.cooldown_until: float = 0.0  # anti-thrash for role switching
        self.decode_rr: int = 0           # round-robin cursor over slots
        self._lat_ewma: Optional[float] = None
        self._init_caches()

    # ------------------------------------------------------------- memory
    def weights_bytes(self) -> float:
        return cm.weights_bytes(self.cfg,
                                include_encoder=self.role in E_ROLES,
                                include_llm=self.role in P_ROLES | D_ROLES)

    def free_memory(self) -> float:
        return self.chips * self.hw.mem_bytes - self.weights_bytes()

    def _init_caches(self) -> None:
        self.mm_cache: Optional[MMBlockManager] = None
        self.kv_cache: Optional[KVBlockManager] = None
        free = max(0.0, self.free_memory())
        # paper §3.2: E workers hold an MM cache; P workers hold BOTH the MM
        # cache (receiving ψ_EP transfers) and the KV cache; D only KV.
        if self.role in E_ROLES or self.role == "P":
            self.mm_cache = MMBlockManager(self.mm_blocks, self.block_size)
        if self.role in P_ROLES | D_ROLES:
            kv_tok = self.cfg.kv_bytes_per_token(cm.DTYPE_BYTES)
            budget = free * self.kv_frac
            n_blocks = max(1, int(budget / max(kv_tok, 1) / self.block_size))
            self.kv_cache = KVBlockManager(n_blocks, self.block_size)

    # ------------------------------------------------------------- latency
    def observe_latency(self, seconds: float) -> None:
        """Fold one observed per-job service latency into the EWMA the
        latency-aware router reads (straggler shedding)."""
        self._lat_ewma = (seconds if self._lat_ewma is None
                          else 0.3 * seconds + 0.7 * self._lat_ewma)

    def latency_ms(self) -> float:
        return 0.0 if self._lat_ewma is None else self._lat_ewma * 1e3

    # ---------------------------------------------------------------- load
    def load(self) -> float:
        """Queued work in estimated seconds (least-loaded routing and the
        role-switch monitor both read this)."""
        q = sum(self.estimate(j) for j in self.queue)
        if self.decode_slots:
            n = len(self.decode_slots)
            steps = sum(s.remaining for s in self.decode_slots) / n
            waves = -(-n // self.decode_batch)
            q += self.decode_step_time() * steps * waves
        return q

    def estimate(self, job) -> float:
        if isinstance(job, EncodeJob):
            return cm.encode_time(self.cfg, self.hw, job.n_patches,
                                  chips=self.chips)
        if isinstance(job, PrefillJob):
            return cm.prefill_time(self.cfg, self.hw, job.seq_len,
                                   chips=self.chips)
        raise TypeError(job)

    def _units(self, job) -> int:
        """Occupancy units a job brings to a batch (batch_eff argument)."""
        if isinstance(job, EncodeJob):
            return max(1, min(job.n_patches, 8))
        return max(1, job.seq_len // 512)

    def batched_time(self, jobs: list) -> float:
        """Service time of a co-scheduled batch: per-job compute normalized
        to full utilization, re-divided by the batch's joint utilization,
        one shared launch overhead."""
        tot_u = sum(self._units(j) for j in jobs)
        eff_tot = cm.batch_eff(tot_u)
        t = 0.0
        for j in jobs:
            base = self.estimate(j) - self.hw.step_overhead
            t += base * cm.batch_eff(self._units(j)) / eff_tot
        return t + self.hw.step_overhead

    def decode_step_time(self) -> float:
        n = len(self.decode_slots)
        if n == 0:
            return 0.0
        ctx = sum(s.context for s in self.decode_slots) / n
        return cm.decode_step_time(self.cfg, self.hw, int(ctx),
                                   chips=self.chips, batch=n)

    # -------------------------------------------------------- role switch
    def switch_role(self, new_role: str) -> float:
        """Returns the switch latency; offloading queued work is the
        cluster's job (paper: offload -> migrate -> onload)."""
        if new_role == self.role:
            return 0.0
        e_involved = ("E" in (self.role, new_role)
                      or self.role in ("EP", "EPD")
                      or new_role in ("EP", "EPD"))
        lat = SWITCH_LATENCY_E if e_involved else SWITCH_LATENCY_PD
        self.role = new_role
        self._init_caches()
        return lat

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Instance(id={self.id}, role={self.role}, chips={self.chips},"
                f" q={len(self.queue)}, d={len(self.decode_slots)})")
