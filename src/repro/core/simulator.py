"""Discrete-event simulator of the disaggregated serving cluster.

The paper's own resource allocator runs on "a simulator extended from
DistServe" (§3.2.3); this module is that simulator, extended to the full
EPD pipeline: IRP sharding, MM/KV block-manager gating, asynchronous EP/PD
migrations, continuous-batching decode, and dynamic role switching. The
aggregated baselines fall out as degenerate role sets:

  vLLM       -> every instance 'EPD' (one serialized executor: encode,
                prefill and decode steps interfere, Fig. 1 top)
  DistServe  -> 'EP' + 'D' instances (prefill-decode disaggregation only)
  EPD (ours) -> 'E' + 'P' + 'D' instances (+ IRP + role switching)

Scheduler, block managers and migration logic are the *real* framework code
paths; only stage service times come from the analytical cost model.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.block_manager import OutOfBlocks
from repro.core.faults import FaultPlan
from repro.core.instance import (DecodeSlot, EncodeJob, Instance, PrefillJob,
                                 D_ROLES, E_ROLES, P_ROLES)
from repro.core.request import Request
from repro.core.scheduler import (FCFS, LEAST_LOADED, ROUND_ROBIN, Assigner,
                                  order_queue)

ARRIVAL = "arrival"
JOB_DONE = "job_done"
DECODE_STEP = "decode_step"
EP_DONE = "ep_transfer_done"
PD_DONE = "pd_transfer_done"
MONITOR = "monitor"
ONLOAD = "onload"
FAULT_DEATH = "fault_death"
WAKE = "wake"


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class Simulator:
    def __init__(self, cfg: ArchConfig, hw: cm.HardwareProfile,
                 instances: list[Instance], *,
                 assign_policy: str = LEAST_LOADED,
                 queue_policy: str = FCFS,
                 irp: bool = True,
                 irp_degree: int = 0,           # 0 = all E instances
                 role_switch: bool = False,
                 monitor_interval: float = 2.0,
                 switch_threshold: float = 3.0,
                 transfer_links: int = 1,
                 faults: Optional[FaultPlan] = None,
                 verbose: bool = False):
        self.cfg = cfg
        self.hw = hw
        self.instances = instances
        self.assigner = Assigner(assign_policy)
        self.queue_policy = queue_policy
        self.irp = irp
        self.irp_degree = irp_degree
        self.role_switch = role_switch
        self.monitor_interval = monitor_interval
        self.switch_threshold = switch_threshold
        self.transfer_links = transfer_links
        self.faults = faults or FaultPlan()
        self.verbose = verbose
        # structural fault metrics (names match the real engine's
        # ServeStats keys so sim-vs-real cross-validation compares directly)
        self.fault_stats = {"instance_deaths": 0, "fault_failovers": 0,
                            "fault_replays": 0, "jobs_rerouted": 0,
                            "stranded": 0}

        self._events: list[Event] = []
        self._seq = itertools.count()
        self.requests: dict[int, Request] = {}
        self.now = 0.0
        self.switch_log: list[tuple[float, int, str, str]] = []

    # ------------------------------------------------------------ helpers
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, Event(t, next(self._seq), kind, payload))

    def stage(self, letter: str) -> list[Instance]:
        roles = {"E": E_ROLES, "P": P_ROLES, "D": D_ROLES}[letter]
        return [i for i in self.instances
                if i.role in roles and i.accepting and i.alive]

    def _pos(self, inst: Instance) -> int:
        """FaultPlan addresses instances by position in spec order (the
        global ``Instance.id`` counter is not cluster-relative)."""
        return self.instances.index(inst)

    def _assign(self, letter: str) -> Instance:
        insts = self.stage(letter)
        return insts[self.assigner.pick(insts)]

    # ---------------------------------------------------------------- run
    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.requests[r.req_id] = r
            self._push(r.arrival, ARRIVAL, r.req_id)
        if self.role_switch:
            self._push(self.monitor_interval, MONITOR)
        for d in self.faults.deaths:
            if 0 <= d.iid < len(self.instances):
                self._push(d.at, FAULT_DEATH, d.iid)
        for s in self.faults.stalls:
            if 0 <= s.iid < len(self.instances):
                self._push(s.end, WAKE, s.iid)

        while self._events:
            ev = heapq.heappop(self._events)
            self.now = ev.time
            if ev.kind == MONITOR and not self._pending_work():
                continue  # drain: no more monitoring once work is done
            getattr(self, "_on_" + ev.kind)(ev)
        return list(self.requests.values())

    def _pending_work(self) -> bool:
        if any(not r.done() for r in self.requests.values()):
            return True
        return False

    # -------------------------------------------------------------- events
    def _on_arrival(self, ev: Event) -> None:
        req = self.requests[ev.payload]
        if req.n_patches > 0 and self.stage("E"):
            e_insts = self.stage("E")
            shards = 1
            if self.irp:
                cap = self.irp_degree or len(e_insts)
                shards = max(1, min(cap, req.n_patches))
            base, rem = divmod(req.n_patches, shards)
            req.enc_start = self.now
            req.shard_done = [False] * shards
            for s in range(shards):
                n = base + (1 if s < rem else 0)
                inst = self._assign("E")
                job = EncodeJob(req.req_id, n, s, shards)
                self._admit_encode(inst, job)
        else:
            req.enc_start = req.enc_end = req.ep_transfer_end = self.now
            self._enqueue_prefill(req)

    def _admit_encode(self, inst: Instance, job: EncodeJob) -> None:
        req = self.requests[job.req_id]
        tokens = job.n_patches * req.tokens_per_patch
        if inst.mm_cache is not None:
            try:
                inst.mm_cache.allocate(req.req_id, max(1, tokens))
            except OutOfBlocks:
                pass  # queue anyway; blocks are rechecked at service time
        inst.queue.append(job)
        self._kick(inst)

    def _enqueue_prefill(self, req: Request) -> None:
        try:
            inst = self._assign("P")
        except RuntimeError:      # every P-capable instance is dead
            self._strand(req.req_id)
            return
        inst.queue.append(PrefillJob(req.req_id, req.prefill_tokens))
        self._kick(inst)

    # ---------------------------------------------------- instance engine
    def _stalled(self, inst: Instance) -> bool:
        """Park a stalled instance until the stall's end (a WAKE event is
        scheduled at plan-install time to re-kick it)."""
        end = self.faults.stall_until(self._pos(inst), self.now)
        if end > self.now:
            inst.busy_until = max(inst.busy_until, end)
            return True
        return False

    def _kick(self, inst: Instance) -> None:
        """Start the next batch on an idle instance."""
        if inst.busy_until > self.now or not inst.accepting or not inst.alive:
            return
        if self._stalled(inst):
            return
        if inst.queue:
            ordered = order_queue(inst.queue, self.queue_policy, inst.estimate)
            head = ordered[0]
            kind = type(head)
            batch = [j for j in ordered if isinstance(j, kind)][:inst.max_batch]
            if isinstance(head, PrefillJob):
                batch = self._admit_prefill_batch(inst, batch)
                if not batch:
                    # KV blocks exhausted: wait for a decode to finish
                    self._maybe_decode(inst)
                    return
            for j in batch:
                inst.queue.remove(j)
            service = self._service_time(inst, batch)
            inst.observe_latency(service / len(batch))
            inst.busy_until = self.now + service
            self._push(inst.busy_until, JOB_DONE, (inst.id, batch))
            return
        self._maybe_decode(inst)

    def _admit_prefill_batch(self, inst: Instance, batch: list) -> list:
        """Admit prefill jobs whose KV allocation fits (paged gating)."""
        admitted = []
        for j in batch:
            req = self.requests[j.req_id]
            need = req.prefill_tokens + req.output_len
            if inst.kv_cache is None or inst.kv_cache.can_allocate(need):
                if inst.kv_cache is not None:
                    inst.kv_cache.allocate(req.req_id, need)
                admitted.append(j)
            elif inst.kv_cache.blocks_for(need) > inst.kv_cache.n_blocks \
                    and not inst.decode_slots and not admitted:
                # can NEVER fit: admit degraded instead of deadlocking
                admitted.append(j)
        return admitted

    def _service_time(self, inst: Instance, batch: list) -> float:
        # injected slowdowns (limplock): the degraded node still serves,
        # just proportionally slower
        return (inst.batched_time(batch)
                * self.faults.multiplier(self._pos(inst), self.now))

    def _maybe_decode(self, inst: Instance) -> None:
        if inst.role not in D_ROLES or not inst.decode_slots:
            return
        if inst.busy_until > self.now or not inst.alive:
            return
        if self._stalled(inst):
            return
        step = (inst.decode_step_time()
                * self.faults.multiplier(self._pos(inst), self.now))
        inst.observe_latency(step)
        inst.busy_until = self.now + step
        # rotate the slot window: with residency > decode_batch a fixed
        # [:n] prefix starves the tail behind long-output heads forever
        slots = inst.decode_slots
        n = min(len(slots), inst.decode_batch)
        start = inst.decode_rr % len(slots)
        batch = (slots[start:] + slots[:start])[:n]
        inst.decode_rr += n
        self._push(inst.busy_until, DECODE_STEP,
                   (inst.id, [s.req_id for s in batch]))

    def _inst(self, iid: int) -> Instance:
        return next(i for i in self.instances if i.id == iid)

    def _on_job_done(self, ev: Event) -> None:
        iid, batch = ev.payload
        inst = self._inst(iid)
        if not inst.alive:
            # died mid-batch: the in-flight work is lost; re-dispatch each
            # job to a surviving sibling of its stage
            for job in batch:
                letter = "E" if isinstance(job, EncodeJob) else "P"
                sibs = self.stage(letter)
                if sibs:
                    tgt = sibs[self.assigner.pick(sibs)]
                    tgt.queue.append(job)
                    self.fault_stats["jobs_rerouted"] += 1
                    self._kick(tgt)
                else:
                    self._strand(job.req_id)
            return
        for job in batch:
            req = self.requests[job.req_id]
            if isinstance(job, EncodeJob):
                req.shard_done[job.shard_id] = True
                if all(req.shard_done):
                    req.enc_end = self.now
                    by = cm.ep_transfer_bytes(self.cfg, req.mm_tokens)
                    if inst.role == "E":  # disaggregated: real EP migration
                        t = cm.transfer_time(by, self.hw,
                                             links=self.transfer_links)
                    else:                 # aggregated: tokens already local
                        t = 0.0
                    self._push(self.now + t, EP_DONE, (inst.id, req.req_id))
            elif isinstance(job, PrefillJob):
                req.prefill_end = self.now  # first token
                if inst.role in ("P", "EP"):
                    # disaggregated decode: the KV cache migrates
                    by = cm.pd_transfer_bytes(self.cfg, req.prefill_tokens)
                    t = cm.transfer_time(by, self.hw,
                                         links=self.transfer_links)
                    self._push(self.now + t, PD_DONE, (inst.id, req.req_id))
                else:
                    self._push(self.now, PD_DONE, (inst.id, req.req_id))
        self._kick(inst)

    def _on_ep_transfer_done(self, ev: Event) -> None:
        iid, rid = ev.payload
        inst = self._inst(iid)
        req = self.requests[rid]
        req.ep_transfer_end = self.now
        # clear encode-side MM blocks (paper §3.2.1)
        for i in self.instances:
            if i.mm_cache is not None and i.role == "E":
                i.mm_cache.free(rid)
        if inst.role in ("EP", "EPD") and inst.alive:
            # aggregated: prefill runs on the same instance
            inst.queue.append(PrefillJob(rid, req.prefill_tokens))
            self._kick(inst)
        else:
            self._enqueue_prefill(req)

    def _on_pd_transfer_done(self, ev: Event) -> None:
        iid, rid = ev.payload
        src = self._inst(iid)
        req = self.requests[rid]
        req.pd_transfer_end = self.now
        req.decode_start = self.now
        if src.role in ("EPD",) and src.alive:
            dst = src                   # decode in place
        else:
            try:
                dst = self._assign("D")
            except RuntimeError:  # every D-capable instance is dead
                self._strand(rid)
                return
        if dst is not src and src.kv_cache is not None:
            src.kv_cache.free(rid)      # KV left the prefill worker
            self._kick(src)             # blocked prefills may now admit
        if dst is not src and dst.kv_cache is not None:
            try:
                dst.kv_cache.allocate(rid, req.total_context)
            except OutOfBlocks:
                pass  # decode proceeds degraded; real system would retry
        if req.output_len <= 1:
            req.finish = self.now
            if dst.kv_cache is not None:
                dst.kv_cache.free(rid)
            return
        dst.decode_slots.append(
            DecodeSlot(rid, req.prefill_tokens + 1, req.output_len - 1))
        self._maybe_decode(dst)
        self._kick(dst)

    def _on_decode_step(self, ev: Event) -> None:
        iid, rids = ev.payload
        inst = self._inst(iid)
        if not inst.alive:
            return    # residents were re-homed by the death handler
        done_ids = []
        for slot in list(inst.decode_slots):
            if slot.req_id not in rids:
                continue
            slot.context += 1
            slot.remaining -= 1
            if slot.remaining <= 0:
                req = self.requests[slot.req_id]
                req.finish = self.now
                inst.decode_slots.remove(slot)
                if inst.kv_cache is not None:
                    inst.kv_cache.free(slot.req_id)
                done_ids.append(slot.req_id)
        # aggregated roles: queued encode/prefill work may preempt decode
        self._kick(inst)
        self._maybe_decode(inst)

    # ------------------------------------------------------------- faults
    def _strand(self, rid: int) -> None:
        """No surviving instance can take this request: mark it finished
        so the run drains, and count it (tests assert stranded == 0)."""
        req = self.requests[rid]
        if not req.done():
            req.finish = self.now
            self.fault_stats["stranded"] += 1

    def _on_wake(self, ev: Event) -> None:
        inst = self.instances[ev.payload]
        if inst.alive:
            self._kick(inst)
            self._maybe_decode(inst)

    def _on_fault_death(self, ev: Event) -> None:
        """Injected instance death: re-home its queue and decode residents
        exactly as the real ClusterEngine's failover sweep does — decode
        residents migrate to a D sibling when the dead node's KV is
        reachable, else replay from the prompt through a P sibling."""
        pos = ev.payload
        inst = self.instances[pos]
        if not inst.alive:
            return
        death = self.faults.death_for(pos)
        inst.alive = False
        inst.accepting = False
        self.fault_stats["instance_deaths"] += 1
        # queued (not-yet-started) jobs reroute losslessly
        jobs, inst.queue = inst.queue, []
        for job in jobs:
            letter = "E" if isinstance(job, EncodeJob) else "P"
            sibs = self.stage(letter)
            if sibs:
                tgt = sibs[self.assigner.pick(sibs)]
                tgt.queue.append(job)
                self.fault_stats["jobs_rerouted"] += 1
                self._kick(tgt)
            else:
                self._strand(job.req_id)
        # decode residents: migrate (KV reachable) or replay from prompt
        kv_ok = death.kv_reachable if death is not None else True
        slots, inst.decode_slots = inst.decode_slots, []
        for slot in slots:
            if inst.kv_cache is not None:
                inst.kv_cache.free(slot.req_id)
            sibs = [i for i in self.stage("D") if i is not inst]
            if kv_ok and sibs:
                tgt = sibs[self.assigner.pick(sibs)]
                tgt.decode_slots.append(slot)
                if tgt.kv_cache is not None:
                    try:
                        tgt.kv_cache.allocate(
                            slot.req_id, slot.context + slot.remaining)
                    except OutOfBlocks:
                        pass
                self.fault_stats["fault_failovers"] += 1
                self._maybe_decode(tgt)
                continue
            psibs = self.stage("P")
            if psibs:
                req = self.requests[slot.req_id]
                tgt = psibs[self.assigner.pick(psibs)]
                tgt.queue.append(PrefillJob(slot.req_id, req.prefill_tokens))
                self.fault_stats["fault_replays"] += 1
                self._kick(tgt)
            else:
                self._strand(slot.req_id)

    # -------------------------------------------------------- role switch
    def _stage_pressure(self, letter: str) -> float:
        insts = self.stage(letter)
        if not insts:
            return 0.0
        return sum(i.load() for i in insts) / len(insts)

    def _on_monitor(self, ev: Event) -> None:
        self._push(self.now + self.monitor_interval, MONITOR)
        stages = [s for s in "EPD" if self.stage(s)]
        if len(stages) < 2:
            return
        pressures = {s: self._stage_pressure(s) for s in stages}
        hot = max(pressures, key=pressures.get)
        # candidate donors: stages with >1 instance and low pressure
        donors = [s for s in stages
                  if s != hot and len(self.stage(s)) > 1
                  and pressures[s] * self.switch_threshold <= pressures[hot] + 1e-9
                  and pressures[hot] > 0.0]
        if not donors:
            return
        cold = min(donors, key=pressures.get)
        ready = [i for i in self.stage(cold)
                 if i.cooldown_until <= self.now]
        if not ready:
            return
        donor = min(ready, key=lambda i: i.load())
        donor.cooldown_until = self.now + 4 * self.monitor_interval
        self._do_switch(donor, hot)

    def _do_switch(self, inst: Instance, new_role: str) -> None:
        """Offload -> migrate -> onload (paper §3.2.4)."""
        old_role = inst.role
        inst.accepting = False
        # offload queued jobs to siblings of the old stage
        jobs, inst.queue = inst.queue, []
        for job in jobs:
            letter = "E" if isinstance(job, EncodeJob) else "P"
            siblings = self.stage(letter)
            if siblings:
                tgt = siblings[self.assigner.pick(siblings)]
                tgt.queue.append(job)
                self._kick(tgt)
        # in-flight decode slots migrate to a sibling D instance (their KV
        # moves with them); without a sibling the switch is aborted
        if inst.decode_slots and new_role not in D_ROLES:
            sibs = [i for i in self.stage("D") if i is not inst]
            if not sibs:
                inst.accepting = True
                return
            slots, inst.decode_slots = inst.decode_slots, []
            for slot in slots:
                tgt = sibs[self.assigner.pick(sibs)]
                tgt.decode_slots.append(slot)
                if inst.kv_cache is not None:
                    inst.kv_cache.free(slot.req_id)
                if tgt.kv_cache is not None:
                    try:
                        tgt.kv_cache.allocate(
                            slot.req_id, slot.context + slot.remaining)
                    except OutOfBlocks:
                        pass
                self._maybe_decode(tgt)
        lat = inst.switch_role(new_role)
        self.switch_log.append((self.now, inst.id, old_role, new_role))
        self._push(self.now + lat, ONLOAD, inst.id)

    def _on_onload(self, ev: Event) -> None:
        inst = self._inst(ev.payload)
        inst.accepting = True
        self._kick(inst)
