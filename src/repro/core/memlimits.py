"""Memory-limit calculators behind Tables 2, 3 and 8 (and Fig 2).

Mechanistic model: a worker's device memory holds
  weights(role) + KV-cache reservation + encode activations + MM tokens.
Max-images / max-batch / max-KV%-questions solve that budget for one
unknown. OOM = even the minimum doesn't fit; OOCL = the token count
exceeds the model's context limit (paper App. A.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Union

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm

OOM = "OOM"
OOCL = "OOCL"
Result = Union[int, str]


def _budget(cfg: ArchConfig, hw: cm.HardwareProfile, role: str,
            kv_frac: float, kv_context: int = 0) -> float:
    """Free bytes for encode/prefill payloads after weights + KV budget."""
    w = cm.weights_bytes(cfg,
                         include_encoder=role in ("E", "EP", "EPD"),
                         include_llm=role != "E")
    free = hw.mem_bytes - w
    if role != "E":
        free -= kv_frac * max(free, 0.0)
    return free


def _per_patch_bytes(cfg: ArchConfig) -> float:
    m = cfg.modality
    return (cm.encode_activation_bytes(cfg, 1)
            + cm.mm_token_bytes(cfg, m.tokens_per_item))


def effective_patches(cfg: ArchConfig, resolution, n_images: int) -> int:
    """Patches per image: InternVL-style tiling divides a fixed tile budget
    across a request's images; MiniCPM slices every image independently."""
    m = cfg.modality
    patches = m.patches_at_res[resolution]
    if m.tile_budget and n_images > 0:
        patches = min(patches, max(1, m.tile_budget // n_images))
    return patches


def max_images_per_request(cfg: ArchConfig, hw: cm.HardwareProfile,
                           role: str, resolution: tuple[int, int], *,
                           kv_frac: float = 0.8) -> Result:
    """Table 2: max #images in ONE request (batch 1)."""
    m = cfg.modality
    free = _budget(cfg, hw, role, kv_frac)
    best: Result = OOM
    n = 1
    while True:
        patches = effective_patches(cfg, resolution, n)
        tokens = n * patches * m.tokens_per_item
        if tokens + 64 > cfg.max_context:
            return best if best != OOM else OOCL
        if n * patches * _per_patch_bytes(cfg) > free:
            return best
        best = n
        n += 1


def max_batch(cfg: ArchConfig, hw: cm.HardwareProfile, role: str,
              resolution: tuple[int, int], *, images_per_req: int = 10,
              kv_frac: float = 0.8) -> Result:
    """Table 3: max concurrent requests in the E / P stage."""
    m = cfg.modality
    patches = effective_patches(cfg, resolution, images_per_req)
    free = _budget(cfg, hw, role, kv_frac)
    if role in ("P", "EP", "EPD"):
        # prefill must also hold each request's KV for prompt+mm tokens
        tokens = images_per_req * patches * m.tokens_per_item + 64
        per_req = images_per_req * patches * _per_patch_bytes(cfg) \
            + cm.kv_bytes(cfg, tokens)
        if role == "P":
            # disaggregated P has no encoder: only mm tokens + KV
            per_req = (images_per_req * patches
                       * cm.mm_token_bytes(cfg, m.tokens_per_item)
                       + cm.kv_bytes(cfg, tokens))
    else:
        per_req = images_per_req * patches * _per_patch_bytes(cfg)
    n = int(free / per_req)
    return n if n >= 1 else OOM


def max_kv_percent(cfg: ArchConfig, hw: cm.HardwareProfile, role: str, *,
                   images_per_req: int, resolution=(4032, 3024)) -> Result:
    """Table 8: largest KV-cache fraction (of free memory) on the prefill
    node that still fits one request of ``images_per_req`` 4K images."""
    m = cfg.modality
    patches = effective_patches(cfg, resolution, images_per_req)
    tokens = images_per_req * patches * m.tokens_per_item + 64
    if tokens > cfg.max_context:
        return OOCL
    w = cm.weights_bytes(cfg, include_encoder=role in ("EP", "EPD"),
                         include_llm=True)
    free = hw.mem_bytes - w
    payload = images_per_req * patches * (
        _per_patch_bytes(cfg) if role in ("EP", "EPD")
        else cm.mm_token_bytes(cfg, m.tokens_per_item))
    payload += cm.kv_bytes(cfg, tokens)  # the request's own KV
    pct = (free - payload) / free * 100.0
    if pct <= 0:
        return OOM
    return int(round(min(pct, 99.0)))
