"""Cluster construction + serving metrics.

``build_cluster`` turns a config string like "5E2P1D" (paper notation:
5 encode, 2 prefill, 1 decode instances) into instances; vLLM / DistServe
baselines use "8EPD" / "7EP1D"-style specs. ``simulate`` wires a Simulator;
``summarize`` computes the paper's metrics (TTFT / TPOT / SLO attainment),
and ``goodput`` sweeps request rates for the max rate with >=90% attainment.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.instance import Instance
from repro.core.request import SLO, Request
from repro.core.scheduler import FCFS, LEAST_LOADED
from repro.core.simulator import Simulator

_SPEC_RE = re.compile(r"(\d+)(EPD|EP|E|P|D)")


@dataclass
class ClusterSpec:
    spec: str                            # e.g. "5E2P1D", "8EPD", "7EP1D"
    chips_per_instance: int = 1
    max_batch: int = 8
    decode_batch: int = 128
    kv_frac: float = 0.5                 # paper E.1: KV utilization 50%
    irp: bool = True
    irp_degree: int = 0
    role_switch: bool = False
    assign_policy: str = LEAST_LOADED
    queue_policy: str = FCFS
    # heterogeneous clusters (paper App. A.3): one HardwareProfile per
    # instance, aligned with roles(); None = homogeneous
    hw_mix: Optional[list] = None

    def roles(self) -> list[str]:
        out = []
        for count, role in _SPEC_RE.findall(self.spec):
            out.extend([role] * int(count))
        if not out:
            raise ValueError(f"bad cluster spec {self.spec!r}")
        return out

    @property
    def n_chips(self) -> int:
        return len(self.roles()) * self.chips_per_instance


def build_cluster(spec: ClusterSpec, cfg: ArchConfig,
                  hw: cm.HardwareProfile) -> list[Instance]:
    roles = spec.roles()
    mix = spec.hw_mix or [hw] * len(roles)
    if len(mix) != len(roles):
        raise ValueError(f"hw_mix has {len(mix)} entries for "
                         f"{len(roles)} instances")
    return [Instance(role, spec.chips_per_instance, cfg, h,
                     max_batch=spec.max_batch, decode_batch=spec.decode_batch,
                     kv_frac=spec.kv_frac)
            for role, h in zip(roles, mix)]


def simulate(spec: ClusterSpec, cfg: ArchConfig, hw: cm.HardwareProfile,
             requests: Sequence[Request], **sim_kw) -> list[Request]:
    instances = build_cluster(spec, cfg, hw)
    sim = Simulator(cfg, hw, instances,
                    assign_policy=spec.assign_policy,
                    queue_policy=spec.queue_policy,
                    irp=spec.irp, irp_degree=spec.irp_degree,
                    role_switch=spec.role_switch, **sim_kw)
    return sim.run([_clone(r) for r in requests])


def _clone(r: Request) -> Request:
    return Request(req_id=r.req_id, arrival=r.arrival,
                   prompt_len=r.prompt_len, n_items=r.n_items,
                   patches_per_item=r.patches_per_item,
                   tokens_per_patch=r.tokens_per_patch,
                   output_len=r.output_len, slo=r.slo)


# ------------------------------------------------------------------ metrics
@dataclass
class Summary:
    n: int
    ttft_mean: float
    ttft_p50: float
    ttft_p99: float
    tpot_mean: float
    latency_mean: float
    slo_attainment: float

    def row(self) -> dict:
        return self.__dict__.copy()


def summarize(requests: Sequence[Request],
              slo: Optional[SLO] = None) -> Summary:
    done = [r for r in requests if r.done()]
    assert done, "no request finished"
    ttfts = np.array([r.ttft for r in done])
    tpots = np.array([r.tpot for r in done])
    lats = np.array([r.e2e_latency for r in done])
    att = float(np.mean([r.attains(slo) for r in done])) if (
        slo or all(r.slo for r in done)) else float("nan")
    return Summary(
        n=len(done),
        ttft_mean=float(ttfts.mean()),
        ttft_p50=float(np.percentile(ttfts, 50)),
        ttft_p99=float(np.percentile(ttfts, 99)),
        tpot_mean=float(tpots.mean()),
        latency_mean=float(lats.mean()),
        slo_attainment=att,
    )


def goodput(make_requests, spec: ClusterSpec, cfg: ArchConfig,
            hw: cm.HardwareProfile, *, rates: Sequence[float],
            slo: SLO, threshold: float = 0.9) -> float:
    """Paper metric: highest rate with >= 90% SLO attainment."""
    best = 0.0
    for rate in sorted(rates):
        reqs = make_requests(rate)
        out = simulate(spec, cfg, hw, reqs)
        if summarize(out, slo).slo_attainment >= threshold:
            best = rate
    return best
