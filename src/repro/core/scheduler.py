"""Scheduling policies (paper Appendix D, "Scheduling").

Two decisions: (1) which instance gets a request — Round-Robin or
Least-Loaded-First across the instances of a stage; (2) ordering within an
instance queue — FCFS or Shortest-Job-First (by estimated service time).
All instances within a stage share one strategy, as in the paper.
"""
from __future__ import annotations

from typing import Callable, Sequence

FCFS = "fcfs"
SJF = "sjf"
ROUND_ROBIN = "round_robin"
LEAST_LOADED = "least_loaded"
LATENCY_AWARE = "latency_aware"


class Assigner:
    """Routes jobs to one of a stage's instances."""

    def __init__(self, policy: str = ROUND_ROBIN):
        if policy not in (ROUND_ROBIN, LEAST_LOADED, LATENCY_AWARE):
            raise ValueError(policy)
        self.policy = policy
        self._rr = 0

    def pick(self, instances: Sequence) -> int:
        alive = [i for i, inst in enumerate(instances) if inst.accepting]
        if not alive:
            raise RuntimeError("no accepting instance in stage")
        if self.policy == ROUND_ROBIN:
            idx = alive[self._rr % len(alive)]
            self._rr += 1
            return idx
        if self.policy == LATENCY_AWARE:
            # least-loaded, with queued work inflated by how much slower
            # this instance's observed service latency runs than the
            # fastest peer's — a limping instance sheds load before it dies
            lats = {i: float(getattr(instances[i], "latency_ms",
                                     lambda: 0.0)())
                    for i in alive}
            base = min((l for l in lats.values() if l > 0.0), default=0.0)

            def score(i: int) -> float:
                rel = (lats[i] / base) if base > 0.0 and lats[i] > 0.0 else 1.0
                return (instances[i].load() + 1.0) * max(rel, 1.0)

            return min(alive, key=score)
        return min(alive, key=lambda i: instances[i].load())


def order_queue(queue: list, policy: str, est: Callable) -> list:
    """Return the queue in service order. ``est(job)`` = predicted time."""
    if policy == FCFS:
        return queue
    if policy == SJF:
        return sorted(queue, key=est)
    raise ValueError(policy)
