"""Optimized resource allocation (paper §3.2.3, Appendix D).

Solves   max_{(p, b, s) in X}  f(p, b, s) − β·cost(p)
where p = instances-per-stage (+ IRP on/off), b = per-stage max batch sizes,
s = scheduling policies — evaluated on the discrete-event simulator, exactly
as the paper does ("we rely on a simulator extended from DistServe").

The optimizer is Bayesian: a small numpy Gaussian process (RBF kernel) over
normalized config vectors with expected-improvement acquisition on a random
candidate pool, seeded by random search. No external dependencies.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.core.request import SLO, Request
from repro.core.scheduler import FCFS, LEAST_LOADED, ROUND_ROBIN, SJF

BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclass(frozen=True)
class AllocConfig:
    """One point in the search space X."""
    n_e: int
    n_p: int
    n_d: int
    batch_e: int
    batch_p: int
    batch_d: int
    irp: bool
    queue_policy: str = FCFS
    assign_policy: str = LEAST_LOADED

    def spec(self) -> ClusterSpec:
        parts = []
        if self.n_e:
            parts.append(f"{self.n_e}E")
        parts.append(f"{self.n_p}P")
        parts.append(f"{self.n_d}D")
        return ClusterSpec("".join(parts),
                           max_batch=max(self.batch_e, self.batch_p),
                           decode_batch=self.batch_d, irp=self.irp,
                           queue_policy=self.queue_policy,
                           assign_policy=self.assign_policy)

    @property
    def n_gpus(self) -> int:
        return self.n_e + self.n_p + self.n_d

    def vector(self) -> np.ndarray:
        return np.array([
            self.n_e, self.n_p, self.n_d,
            math.log2(self.batch_e), math.log2(self.batch_p),
            math.log2(self.batch_d), float(self.irp),
            float(self.queue_policy == SJF),
            float(self.assign_policy == LEAST_LOADED),
        ], dtype=np.float64)


def sample_configs(rng: np.random.Generator, n: int, *, n_gpus: int = 8,
                   exact_gpus: bool = True,
                   require_encode: bool = True) -> list[AllocConfig]:
    """Rejection-sample X under the GPU-budget constraint (Appendix D)."""
    out: list[AllocConfig] = []
    while len(out) < n:
        if require_encode:
            n_e = int(rng.integers(1, n_gpus - 1))
            n_p = int(rng.integers(1, n_gpus - n_e))
        else:
            n_e = 0
            n_p = int(rng.integers(1, n_gpus))
        n_d = (n_gpus - n_e - n_p) if exact_gpus \
            else int(rng.integers(1, n_gpus - n_e - n_p + 1))
        if n_d < 1:
            continue
        cfgc = AllocConfig(
            n_e=n_e, n_p=n_p, n_d=n_d,
            batch_e=int(rng.choice(BATCH_CHOICES[:6])),
            batch_p=int(rng.choice(BATCH_CHOICES[:6])),
            batch_d=int(rng.choice(BATCH_CHOICES[4:])),
            irp=bool(rng.integers(0, 2)),
            queue_policy=str(rng.choice([FCFS, SJF])),
            assign_policy=str(rng.choice([ROUND_ROBIN, LEAST_LOADED])),
        )
        out.append(cfgc)
    return out


# ------------------------------------------------------------ GP + EI (BO)
class _GP:
    def __init__(self, noise: float = 1e-3):
        self.noise = noise
        self.X: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray):
        self.mu = y.mean()
        self.sig = y.std() + 1e-9
        self.X = X
        self.scale = X.std(axis=0) + 1e-9
        Xn = X / self.scale
        self.yn = (y - self.mu) / self.sig
        K = self._k(Xn, Xn) + self.noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(
            self.L.T, np.linalg.solve(self.L, self.yn))
        self.Xn = Xn

    def _k(self, A, B):
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / max(A.shape[1], 1))

    def predict(self, X: np.ndarray):
        Xn = X / self.scale
        Ks = self._k(Xn, self.Xn)
        mean = Ks @ self.alpha
        v = np.linalg.solve(self.L, Ks.T)
        var = np.clip(1.0 - (v ** 2).sum(0), 1e-9, None)
        return mean * self.sig + self.mu, np.sqrt(var) * self.sig


def _ei(mean, std, best):
    z = (mean - best) / std
    phi = np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    Phi = 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))
    return (mean - best) * Phi + std * phi


@dataclass
class BOResult:
    best: AllocConfig
    best_score: float
    history: list = field(default_factory=list)


def optimize_allocation(eval_fn: Callable[[AllocConfig], float], *,
                        n_gpus: int = 8, n_init: int = 8, n_iter: int = 16,
                        require_encode: bool = True, seed: int = 0,
                        beta: float = 0.0, gpu_cost: float = 1.0) -> BOResult:
    """Maximize eval_fn(cfg) − β·cost over X via GP-EI Bayesian optimization."""
    rng = np.random.default_rng(seed)

    def objective(c: AllocConfig) -> float:
        return eval_fn(c) - beta * gpu_cost * c.n_gpus

    tried: dict = {}

    def run(c: AllocConfig) -> float:
        if c not in tried:
            tried[c] = objective(c)
        return tried[c]

    configs = sample_configs(rng, n_init, n_gpus=n_gpus,
                             require_encode=require_encode)
    scores = [run(c) for c in configs]
    history = list(zip(configs, scores))

    gp = _GP()
    for _ in range(n_iter):
        X = np.stack([c.vector() for c, _ in history])
        y = np.array([s for _, s in history])
        gp.fit(X, y)
        pool = sample_configs(rng, 256, n_gpus=n_gpus,
                              require_encode=require_encode)
        Xp = np.stack([c.vector() for c in pool])
        mean, std = gp.predict(Xp)
        cand = pool[int(np.argmax(_ei(mean, std, y.max())))]
        history.append((cand, run(cand)))

    best, best_score = max(history, key=lambda t: t[1])
    return BOResult(best=best, best_score=best_score, history=history)


# --------------------------------------------------- canned objective: goodput
def goodput_objective(cfg: ArchConfig, hw: cm.HardwareProfile,
                      make_requests: Callable[[float], list[Request]],
                      slo: SLO, rates: Sequence[float]):
    """eval_fn measuring goodput (max rate with >=90% SLO attainment)."""
    def eval_fn(alloc: AllocConfig) -> float:
        best = 0.0
        for rate in sorted(rates):
            reqs = make_requests(rate)
            try:
                out = simulate(alloc.spec(), cfg, hw, reqs)
                s = summarize(out, slo)
            except Exception:
                break
            if s.slo_attainment >= 0.9:
                best = rate
            else:
                break
        return best
    return eval_fn
