"""Analytical stage cost & memory model.

The paper evaluates EPD on 8xA100/A800 GPUs and (App. F) on Ascend 910B3
NPUs; its own allocator runs on "a simulator extended from DistServe". This
module is that simulator's cost model, parameterized by a hardware profile —
we add a TPU v5e profile (our deployment target) and keep A100/910B3
profiles to reproduce the paper's tables.

Stage times follow the standard roofline decomposition:
  t = max(FLOPs / (chips·peak·eff), bytes / (chips·hbm_bw)) + fixed overhead
Encode/prefill are compute-bound, decode is bandwidth-bound — exactly the
asymmetry the paper exploits (§B Limitations).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig

DTYPE_BYTES = 2  # fp16/bf16 serving


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float            # per chip, fp16/bf16
    hbm_bw: float                # bytes/s per chip
    link_bw: float               # bytes/s inter-chip (NVLink / ICI)
    mem_bytes: float             # HBM per chip
    mfu_prefill: float = 0.55    # achievable fraction of peak, LLM prefill
    mfu_encode: float = 0.45     # achievable fraction, multimodal encoder
    bw_eff_decode: float = 0.65  # achievable fraction of HBM bw, decode
    step_overhead: float = 2.5e-3  # per-batch scheduling/launch overhead (s)
    # NPUs spend proportionally longer in encode than prefill (paper App F.1:
    # ~10-20% higher encode-to-prefill latency ratio than GPU).
    encode_penalty: float = 1.0


TPU_V5E = HardwareProfile(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
    mem_bytes=16e9)

A100_80G = HardwareProfile(
    name="a100-80g", peak_flops=312e12, hbm_bw=2.039e12, link_bw=300e9,
    mem_bytes=80e9)

NPU_910B3 = HardwareProfile(
    name="npu-910b3", peak_flops=313e12, hbm_bw=1.6e12, link_bw=200e9,
    mem_bytes=64e9, encode_penalty=1.18)  # App F.1: 10-20% heavier encode

PROFILES = {p.name: p for p in (TPU_V5E, A100_80G, NPU_910B3)}


# ----------------------------------------------------------------- FLOPs
def encoder_flops(cfg: ArchConfig, n_patches: int) -> float:
    """Multimodal encoder FLOPs for ``n_patches`` patch-groups.

    Uses the encoder-INTERNAL token count (e.g. 1024 ViT tokens per 448px
    patch), not the compressed output tokens — the compression resampler is
    exactly why MiniCPM is encode-heavy but prefill-light (paper §4.1)."""
    m = cfg.modality
    if m is None or n_patches == 0:
        return 0.0
    tokens = n_patches * m.enc_tokens
    lin = 2.0 * cfg.encoder_param_count() * tokens
    # attention is local per patch-group (IRP shards are independent)
    attn = 4.0 * m.enc_layers * m.enc_tokens ** 2 * m.enc_d_model * n_patches
    return lin + attn


def encoder_mfu(cfg: ArchConfig, hw: HardwareProfile) -> float:
    """Small-width ViTs underutilize the MXU/tensor cores: scale achievable
    MFU with encoder width (InternViT-6B @ d=3200 hits the cap; SigLip-400M
    @ d=1152 lands near 0.17)."""
    m = cfg.modality
    if m is None:
        return hw.mfu_encode
    return min(hw.mfu_encode, max(0.10, 0.5 * m.enc_d_model / 3200.0))


def prefill_flops(cfg: ArchConfig, seq_len: int) -> float:
    lin = 2.0 * cfg.active_param_count() * seq_len
    attn_layers = max(1, len(cfg.attn_layer_ids())) if not cfg.attention_free else 0
    attn = 2.0 * attn_layers * seq_len ** 2 * cfg.n_heads * cfg.head_dim
    return lin + attn


def decode_flops_per_token(cfg: ArchConfig, context: int) -> float:
    lin = 2.0 * cfg.active_param_count()
    if cfg.attention_free:
        return lin
    attn_layers = len(cfg.attn_layer_ids())
    attn = 4.0 * attn_layers * context * cfg.n_kv_heads * cfg.head_dim
    return lin + attn


# ----------------------------------------------------------------- bytes
def weights_bytes(cfg: ArchConfig, include_encoder: bool = True,
                  include_llm: bool = True) -> float:
    enc = cfg.encoder_param_count() * DTYPE_BYTES
    total = cfg.param_count() * DTYPE_BYTES
    out = 0.0
    if include_encoder:
        out += enc
    if include_llm:
        out += total - enc
    return out


def kv_bytes(cfg: ArchConfig, context: int) -> float:
    return cfg.kv_bytes_per_token(DTYPE_BYTES) * context


def mm_token_bytes(cfg: ArchConfig, mm_tokens: int) -> float:
    return mm_tokens * cfg.d_model * DTYPE_BYTES


def encode_activation_bytes(cfg: ArchConfig, n_patches: int,
                            act_factor: float = 70.0) -> float:
    """Peak encoder activation footprint (workspace for attention etc.).

    Uses the encoder-INTERNAL token count (1024 ViT tokens per 448px tile).
    ``act_factor`` ~= live activation copies per token across the encoder —
    calibrated once against paper Table 2 (MiniCPM-V row: 77/490 images at
    313x234 on A100-80G) and reused everywhere."""
    m = cfg.modality
    if m is None:
        return 0.0
    tokens = n_patches * m.enc_tokens
    return tokens * m.enc_d_model * DTYPE_BYTES * act_factor


# ------------------------------------------------------------ stage times
def batch_eff(batch: int) -> float:
    """Small batches underutilize the compute units (launch overhead, low
    occupancy): ~0.55x at batch 1, full utilization from batch 8 up. This is
    what makes the paper's offline scenario (App. A.3) bite: DistServe
    memory-capped at batch 1 loses to EPD batching each stage."""
    import math
    return min(1.0, 0.55 + 0.15 * math.log2(max(batch, 1)))


def encode_time(cfg: ArchConfig, hw: HardwareProfile, n_patches: int, *,
                chips: int = 1, batch: int = 1) -> float:
    """Time for one encode batch; IRP divides patches across ``chips``."""
    if n_patches == 0:
        return 0.0
    fl = encoder_flops(cfg, n_patches) * batch
    # patches within one request batch like items across requests
    eff = batch_eff(batch * max(1, min(n_patches, 8)))
    t_c = fl / (chips * hw.peak_flops * encoder_mfu(cfg, hw) * eff)
    by = (weights_bytes(cfg, include_llm=False)
          + encode_activation_bytes(cfg, n_patches) * batch)
    t_m = by / (chips * hw.hbm_bw)
    pre = (cfg.modality.preprocess_s if cfg.modality else 0.0) \
        * n_patches * batch / chips      # host preprocessing, IRP-parallel
    return (max(t_c, t_m) + pre) * hw.encode_penalty + hw.step_overhead


def prefill_time(cfg: ArchConfig, hw: HardwareProfile, seq_len: int, *,
                 chips: int = 1, batch: int = 1) -> float:
    fl = prefill_flops(cfg, seq_len) * batch
    # long prefills saturate compute on their own; short ones need batching
    eff = batch_eff(batch * max(1, seq_len // 512))
    t_c = fl / (chips * hw.peak_flops * hw.mfu_prefill * eff)
    by = weights_bytes(cfg) + kv_bytes(cfg, seq_len) * batch
    t_m = by / (chips * hw.hbm_bw)
    return max(t_c, t_m) + hw.step_overhead


def decode_step_time(cfg: ArchConfig, hw: HardwareProfile, context: int, *,
                     chips: int = 1, batch: int = 1) -> float:
    """One decode step for a batch (weights read once per step)."""
    by = (weights_bytes(cfg, include_encoder=False)
          + kv_bytes(cfg, context) * batch)
    t_m = by / (chips * hw.hbm_bw * hw.bw_eff_decode)
    fl = decode_flops_per_token(cfg, context) * batch
    t_c = fl / (chips * hw.peak_flops)
    return max(t_m, t_c) + hw.step_overhead


def transfer_time(n_bytes: float, hw: HardwareProfile, *,
                  links: int = 1) -> float:
    """Async EP/PD migration over NVLink/ICI."""
    return 0.1e-3 + n_bytes / (hw.link_bw * links)


def ep_transfer_bytes(cfg: ArchConfig, mm_tokens: int) -> float:
    return mm_token_bytes(cfg, mm_tokens)


def pd_transfer_bytes(cfg: ArchConfig, context: int) -> float:
    return kv_bytes(cfg, context)
