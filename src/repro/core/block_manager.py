"""Paged cache block managers.

``BlockManager`` is the shared paging engine; ``MMBlockManager`` (paper
§3.2.1) manages multimodal-token blocks on E and P workers and pre-allocates
blocks per request; ``KVBlockManager`` manages paged KV blocks on P and D
workers, supports appending blocks as decode grows the sequence, and —
with ``prefix_cache=True`` — adds block-level prefix caching: hash-chained
block keys, per-block refcounts, an LRU free-list of unreferenced cached
blocks, and copy-on-write when a request must write into a shared block.

Invariants (property-tested):
  * without prefix caching, a block is owned by at most one request,
  * used + free == capacity (cached-but-unreferenced blocks count free),
  * freeing a request releases exactly the references it held — a block
    shared with another request (or retained by the prefix index) is
    never returned to the allocatable set while still referenced.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class OutOfBlocks(Exception):
    pass


@dataclass
class BlockManager:
    n_blocks: int
    block_size: int                       # tokens per block
    name: str = "cache"
    _free: list[int] = field(default_factory=list)
    _owned: dict[int, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.n_blocks))

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - self.free_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.free_blocks

    def owner_blocks(self, req_id: int) -> list[int]:
        return list(self._owned.get(req_id, ()))

    # ---------------------------------------------------------- mutations
    def allocate(self, req_id: int, n_tokens: int) -> list[int]:
        """Pre-allocate blocks for a request (paper: MMBlockManager
        'pre-allocates cache blocks based on each request's needs')."""
        need = self.blocks_for(n_tokens)
        if need > len(self._free):
            raise OutOfBlocks(
                f"{self.name}: need {need} blocks, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(need)]
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def append(self, req_id: int, n_new_tokens: int,
               current_tokens: int) -> list[int]:
        """Grow a request's allocation (decode). Only allocates blocks the
        growth actually crosses into."""
        have = len(self._owned.get(req_id, ()))
        need_total = self.blocks_for(current_tokens + n_new_tokens)
        extra = max(0, need_total - have)
        if extra > len(self._free):
            raise OutOfBlocks(f"{self.name}: append needs {extra}")
        blocks = [self._free.pop() for _ in range(extra)]
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def free(self, req_id: int) -> int:
        """Release all blocks of a request (e.g. after EP-migration confirms
        the transfer — 'encoding cache entries are cleared to free memory')."""
        blocks = self._owned.pop(req_id, [])
        self._free.extend(blocks)
        return len(blocks)

    def reset(self) -> None:
        self._owned.clear()
        self._free = list(range(self.n_blocks))


class MMBlockManager(BlockManager):
    """Multimodal-token cache (paper §3.2.1)."""

    def __init__(self, n_blocks: int, block_size: int = 16):
        super().__init__(n_blocks=n_blocks, block_size=block_size, name="mm")


class KVBlockManager(BlockManager):
    """Paged KV cache (vLLM-style), optionally with block-level prefix
    caching — the KV analogue of the ψ_EP multimedia-token cache.

    With ``prefix_cache=True`` every FULL prompt block gets a hash-chained
    key (``key_i = H(key_{i-1}, tokens of block i)``, with an mm-content
    salt folded into the chain root so multimodal prefixes compose with
    the ψ_EP cache). Completed prefills ``commit`` their full blocks into
    a key→block index; a later request maps the longest cached prefix of
    its prompt onto those SHARED blocks (per-block refcounts) and only
    prefills the suffix. ``free`` drops references, never data: a block
    whose refcount hits zero parks on an LRU free-list if indexed (it can
    be re-pinned by a future match) and is only evicted — index entry
    dropped, data reclaimed — when the allocator runs dry. ``cow`` gives
    a request a private copy of a shared block before it writes into one
    (divergence inside a partially-filled block).

    ``on_stat`` (optional) is called with a counter name on evictions and
    copy-on-writes so the serving layer can surface them in ServeStats.
    """

    def __init__(self, n_blocks: int, block_size: int = 16, *,
                 prefix_cache: bool = False,
                 on_stat: Optional[Callable[[str], None]] = None):
        super().__init__(n_blocks=n_blocks, block_size=block_size, name="kv")
        self.prefix_cache = prefix_cache
        self.on_stat = on_stat
        self._ref: dict[int, int] = {}            # block -> live refcount
        self._index: dict[str, int] = {}          # block key -> block id
        self._key_of: dict[int, str] = {}         # block id -> its key
        # refcount-0 indexed blocks, least-recently-used first (only these
        # are evictable; eviction drops the index entry + reclaims data)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # keys an in-flight prefill will produce -> producing req_id (the
        # follower-waits-on-leader dedup of concurrent identical prefills)
        self._inflight: dict[str, int] = {}
        self._inflight_of: dict[int, list[str]] = {}
        self.prefix_evictions = 0
        self.cow_copies = 0

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        # cached-but-unreferenced blocks are reclaimable on demand
        return len(self._free) + len(self._lru)

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def cached_blocks(self) -> int:
        """Blocks currently carrying an index entry (live or LRU)."""
        return len(self._key_of)

    # ---------------------------------------------------------- hash chain
    def chain_keys(self, tokens: np.ndarray, salt: str = "") -> list[str]:
        """Hash-chained keys of every FULL block of ``tokens``. Partial
        tail blocks have no key (their content is not block-complete).
        ``salt`` folds request-invariant context that changes the KV —
        the mm-content hash + mm positions — into the chain root."""
        bs = self.block_size
        toks = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
        parent = hashlib.sha1(salt.encode()).hexdigest()
        keys = []
        for i in range(len(toks) // bs):
            h = hashlib.sha1(parent.encode())
            h.update(toks[i * bs:(i + 1) * bs].tobytes())
            parent = h.hexdigest()
            keys.append(parent)
        return keys

    def match_len(self, keys: list[str]) -> int:
        """Longest prefix of ``keys`` present in the index (no pinning)."""
        n = 0
        for k in keys:
            if k not in self._index:
                break
            n += 1
        return n

    # ---------------------------------------------------- internal plumbing
    def _take_block(self) -> int:
        """One allocatable block: the free list first, then evict the
        least-recently-used unreferenced cached block."""
        if self._free:
            return self._free.pop()
        if self._lru:
            block, _ = self._lru.popitem(last=False)
            key = self._key_of.pop(block)
            del self._index[key]
            self.prefix_evictions += 1
            if self.on_stat is not None:
                self.on_stat("prefix_evictions")
            return block
        raise OutOfBlocks(f"{self.name}: out of blocks "
                          f"(0 free, 0 evictable)")

    def _pin(self, block: int) -> None:
        self._ref[block] = self._ref.get(block, 0) + 1
        self._lru.pop(block, None)

    def _unpin(self, block: int) -> None:
        n = self._ref.get(block, 0) - 1
        if n > 0:
            self._ref[block] = n
            return
        self._ref.pop(block, None)
        if block in self._key_of:
            self._lru[block] = None          # evictable, most-recent last
        else:
            self._free.append(block)

    # ---------------------------------------------------------- mutations
    def allocate(self, req_id: int, n_tokens: int) -> list[int]:
        if not self.prefix_cache:
            return super().allocate(req_id, n_tokens)
        need = self.blocks_for(n_tokens)
        if need > self.free_blocks:
            raise OutOfBlocks(
                f"{self.name}: need {need} blocks, have {self.free_blocks}")
        blocks = [self._take_block() for _ in range(need)]
        for b in blocks:
            self._pin(b)
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def allocate_prefix(self, req_id: int, keys: list[str], n_tokens: int,
                        max_match_blocks: Optional[int] = None,
                        align_blocks: int = 1
                        ) -> Optional[tuple[list[int], int]]:
        """Map the longest cached prefix onto shared blocks and allocate
        private blocks for the rest. Returns ``(block_table, n_matched)``
        or None (allocating nothing) when the pool cannot hold the suffix
        right now. ``max_match_blocks``/``align_blocks`` cap and align the
        match (the two-program oracle needs chunk-aligned suffix starts
        and at least one uncached token)."""
        total = self.blocks_for(n_tokens)
        matched = min(self.match_len(keys), total)
        if max_match_blocks is not None:
            matched = min(matched, max_match_blocks)
        matched = (matched // max(align_blocks, 1)) * max(align_blocks, 1)
        shared = [self._index[k] for k in keys[:matched]]
        for b in shared:
            self._pin(b)
        need = total - matched
        if need > self.free_blocks:
            for b in reversed(shared):
                self._unpin(b)
            return None
        fresh = [self._take_block() for _ in range(need)]
        for b in fresh:
            self._pin(b)
        self._owned.setdefault(req_id, []).extend(shared + fresh)
        return shared + fresh, matched

    def append(self, req_id: int, n_new_tokens: int,
               current_tokens: int) -> list[int]:
        if not self.prefix_cache:
            return super().append(req_id, n_new_tokens, current_tokens)
        have = len(self._owned.get(req_id, ()))
        extra = max(0, self.blocks_for(current_tokens + n_new_tokens) - have)
        if extra > self.free_blocks:
            raise OutOfBlocks(f"{self.name}: append needs {extra}")
        blocks = [self._take_block() for _ in range(extra)]
        for b in blocks:
            self._pin(b)
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def cow(self, req_id: int, idx: int) -> Optional[tuple[int, int]]:
        """Copy-on-write: if logical block ``idx`` of the request's table
        is shared (refcount > 1), swap in a fresh private block and return
        ``(src, dst)`` so the pool owner can copy the data. None when the
        block is already private (no copy needed)."""
        table = self._owned.get(req_id)
        if table is None or not self.prefix_cache:
            return None
        src = table[idx]
        if self._ref.get(src, 0) <= 1:
            return None
        dst = self._take_block()
        self._ref[dst] = 1
        table[idx] = dst
        self._unpin(src)
        self.cow_copies += 1
        if self.on_stat is not None:
            self.on_stat("cow_copies")
        return src, dst

    def free(self, req_id: int) -> int:
        if not self.prefix_cache:
            return super().free(req_id)
        self.clear_inflight(req_id)
        blocks = self._owned.pop(req_id, [])
        for b in blocks:
            self._unpin(b)
        return len(blocks)

    # -------------------------------------------------- index + inflight
    def commit(self, req_id: int, keys: list[str]) -> int:
        """Prefill completed: publish the request's full prompt blocks
        under their chain keys (first producer wins; a racing duplicate
        keeps its private copy unindexed) and clear its in-flight claim.
        Returns the number of newly indexed blocks."""
        self.clear_inflight(req_id)
        if not self.prefix_cache:
            return 0
        table = self._owned.get(req_id, ())
        added = 0
        for i, key in enumerate(keys):
            if i >= len(table) or key in self._index:
                continue
            block = table[i]
            if block in self._key_of:        # already published (shared)
                continue
            self._index[key] = block
            self._key_of[block] = key
            added += 1
        return added

    def register_inflight(self, req_id: int, keys: list[str]) -> None:
        """Claim the keys this request's prefill will produce, so a
        concurrent identical prefill can wait instead of recomputing."""
        if not self.prefix_cache:
            return
        mine = self._inflight_of.setdefault(req_id, [])
        for k in keys:
            if k not in self._index and k not in self._inflight:
                self._inflight[k] = req_id
                mine.append(k)

    def inflight_holder(self, key: str) -> Optional[int]:
        return self._inflight.get(key)

    def clear_inflight(self, req_id: int) -> None:
        for k in self._inflight_of.pop(req_id, ()):
            self._inflight.pop(k, None)

    def reset(self) -> None:
        super().reset()
        self._ref.clear()
        self._index.clear()
        self._key_of.clear()
        self._lru.clear()
        self._inflight.clear()
        self._inflight_of.clear()
