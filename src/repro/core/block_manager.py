"""Paged cache block managers.

``BlockManager`` is the shared paging engine; ``MMBlockManager`` (paper
§3.2.1) manages multimodal-token blocks on E and P workers and pre-allocates
blocks per request; ``KVBlockManager`` manages paged KV blocks on P and D
workers and supports appending blocks as decode grows the sequence.

Invariants (property-tested):
  * a block is owned by at most one request,
  * used + free == capacity,
  * freeing a request returns exactly the blocks it held.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class OutOfBlocks(Exception):
    pass


@dataclass
class BlockManager:
    n_blocks: int
    block_size: int                       # tokens per block
    name: str = "cache"
    _free: list[int] = field(default_factory=list)
    _owned: dict[int, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.n_blocks))

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.free_blocks

    def owner_blocks(self, req_id: int) -> list[int]:
        return list(self._owned.get(req_id, ()))

    # ---------------------------------------------------------- mutations
    def allocate(self, req_id: int, n_tokens: int) -> list[int]:
        """Pre-allocate blocks for a request (paper: MMBlockManager
        'pre-allocates cache blocks based on each request's needs')."""
        need = self.blocks_for(n_tokens)
        if need > len(self._free):
            raise OutOfBlocks(
                f"{self.name}: need {need} blocks, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(need)]
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def append(self, req_id: int, n_new_tokens: int,
               current_tokens: int) -> list[int]:
        """Grow a request's allocation (decode). Only allocates blocks the
        growth actually crosses into."""
        have = len(self._owned.get(req_id, ()))
        need_total = self.blocks_for(current_tokens + n_new_tokens)
        extra = max(0, need_total - have)
        if extra > len(self._free):
            raise OutOfBlocks(f"{self.name}: append needs {extra}")
        blocks = [self._free.pop() for _ in range(extra)]
        self._owned.setdefault(req_id, []).extend(blocks)
        return blocks

    def free(self, req_id: int) -> int:
        """Release all blocks of a request (e.g. after EP-migration confirms
        the transfer — 'encoding cache entries are cleared to free memory')."""
        blocks = self._owned.pop(req_id, [])
        self._free.extend(blocks)
        return len(blocks)

    def reset(self) -> None:
        self._owned.clear()
        self._free = list(range(self.n_blocks))


class MMBlockManager(BlockManager):
    """Multimodal-token cache (paper §3.2.1)."""

    def __init__(self, n_blocks: int, block_size: int = 16):
        super().__init__(n_blocks=n_blocks, block_size=block_size, name="mm")


class KVBlockManager(BlockManager):
    """Paged KV cache (vLLM-style)."""

    def __init__(self, n_blocks: int, block_size: int = 16):
        super().__init__(n_blocks=n_blocks, block_size=block_size, name="kv")
