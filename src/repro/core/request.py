"""Request lifecycle for the EPD pipeline.

A multimodal request flows  E -> (EP-migration) -> P -> (PD-migration) -> D.
``Request`` carries workload description + per-stage timestamps; SLO
attainment and the TTFT/TPOT metrics are derived properties (paper §4,
Evaluation Metrics).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class SLO:
    ttft: float          # seconds
    tpot: float          # seconds/token


@dataclass
class Request:
    req_id: int
    arrival: float                       # seconds
    prompt_len: int                      # text tokens
    n_items: int                         # images / audio clips / video frames
    patches_per_item: int                # encoder jobs per item
    tokens_per_patch: int                # mm tokens produced per patch
    output_len: int                      # tokens to decode
    slo: Optional[SLO] = None

    # ---- per-stage timestamps (filled by the runtime / simulator)
    enc_start: float = -1.0
    enc_end: float = -1.0
    ep_transfer_end: float = -1.0
    prefill_start: float = -1.0
    prefill_end: float = -1.0            # == first token emitted
    pd_transfer_end: float = -1.0
    decode_start: float = -1.0
    finish: float = -1.0

    # IRP bookkeeping: per-shard completion times
    shard_done: list = field(default_factory=list)

    # ------------------------------------------------------------- derived
    @property
    def n_patches(self) -> int:
        return self.n_items * self.patches_per_item

    @property
    def mm_tokens(self) -> int:
        """Multimodal tokens entering prefill (the paper's token inflation)."""
        return self.n_patches * self.tokens_per_patch

    @property
    def prefill_tokens(self) -> int:
        return self.prompt_len + self.mm_tokens

    @property
    def total_context(self) -> int:
        return self.prefill_tokens + self.output_len

    # --------------------------------------------------------------- SLOs
    @property
    def ttft(self) -> float:
        assert self.prefill_end >= 0, "request has not produced a token"
        return self.prefill_end - self.arrival

    @property
    def tpot(self) -> float:
        if self.output_len <= 1:
            return 0.0
        assert self.finish >= 0
        return (self.finish - self.prefill_end) / (self.output_len - 1)

    @property
    def e2e_latency(self) -> float:
        assert self.finish >= 0
        return self.finish - self.arrival

    def attains(self, slo: Optional[SLO] = None) -> bool:
        slo = slo or self.slo
        assert slo is not None
        return self.ttft <= slo.ttft and self.tpot <= slo.tpot

    def done(self) -> bool:
        return self.finish >= 0
