"""RWKV6 full model: embedding + scan over rwkv6 blocks + LM head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.dense import chunked_loss, lm_head
from repro.models.layers import (Params, dense_init, embed_init, rmsnorm,
                                 rmsnorm_init, stack_init)
from repro.models.rwkv6 import rwkv6_block, rwkv6_block_init, rwkv6_init_state

Batch = dict


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "layers": stack_init(ks[1], cfg.n_layers,
                             lambda k: rwkv6_block_init(k, cfg, dtype)),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(ks[2], cfg.d_model, cfg.vocab, dtype),
    }


def _run(params, cfg, tokens, states=None, want_state=False, remat=False):
    x = params["embed"][tokens]

    def body(h, xs):
        if states is None:
            lp, st = xs, None
        else:
            lp, st = xs
        h, new_st = rwkv6_block(lp, cfg, h, state=st, return_state=want_state)
        return h, new_st

    if remat:
        body = jax.checkpoint(body)
    xs = params["layers"] if states is None else (params["layers"], states)
    x, new_states = jax.lax.scan(body, x, xs)
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new_states


def loss_fn(params: Params, cfg: ArchConfig, batch: Batch):
    h, _ = _run(params, cfg, batch["tokens"], remat=True)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    return ce, {"ce": ce}


def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0, **_) -> Batch:
    st = rwkv6_init_state(cfg, batch)
    stacked = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape), st)
    return {"state": stacked, "pos": jnp.zeros((batch,), jnp.int32)}


def prefill(params: Params, cfg: ArchConfig, batch: Batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    states = init_cache(cfg, B)["state"]
    h, new_states = _run(params, cfg, tokens, states, want_state=True)
    logits = lm_head(params, cfg, h[:, -1])
    return logits, {"state": new_states, "pos": jnp.full((B,), S, jnp.int32)}


def decode_step(params: Params, cfg: ArchConfig, batch: Batch):
    cache = batch["cache"]
    token = batch["token"][:, None]                                # (B,1)
    h, new_states = _run(params, cfg, token, cache["state"], want_state=True)
    logits = lm_head(params, cfg, h[:, 0])
    return logits, {"state": new_states, "pos": cache["pos"] + 1}
