"""Whisper-style encoder-decoder.

The mel+conv frontend is a STUB: inputs are precomputed frame embeddings
(B, S_enc, d_model). The transformer encoder (the EPD **E stage**) and the
decoder (P/D stages) are real. Decoder layers: causal self-attn (cached) +
cross-attn over encoder output (cross K/V computed once at prefill) + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (attn_init, cache_write, chunked_attention,
                                    decode_attention, out_project, qkv_project)
from repro.models.dense import chunked_loss, lm_head
from repro.models.encoder import encoder_apply, encoder_init
from repro.models.layers import (Params, dense_init, embed_init, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init, stack_init)

Batch = dict


def dec_layer_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "ln1": rmsnorm_init(d, dtype),
        "self_attn": attn_init(k1, d, H, K, hd, dtype),
        "ln_x": rmsnorm_init(d, dtype),
        "cross_attn": attn_init(k2, d, H, K, hd, dtype),
        "ln2": rmsnorm_init(d, dtype),
        "mlp": mlp_init(k3, d, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    m = cfg.modality
    return {
        "encoder": encoder_init(ks[0], cfg.n_enc_layers, cfg.d_model,
                                m.enc_heads if m else cfg.n_heads,
                                m.enc_d_ff if m else cfg.d_ff, dtype),
        "embed": embed_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "layers": stack_init(ks[2], cfg.n_layers,
                             lambda k: dec_layer_init(k, cfg, dtype)),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab, dtype),
    }


def encode(params: Params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """E stage: (B, S_enc, d_model) stub frame embeds -> encoder output.

    Attention is windowed per audio clip (``tokens_per_item`` frames = one
    30s whisper window) — faithful to whisper's per-window encoder and the
    independence IRP relies on."""
    m = cfg.modality
    return encoder_apply(params["encoder"], frames,
                         heads=m.enc_heads if m else cfg.n_heads,
                         norm_eps=cfg.norm_eps,
                         segment=m.tokens_per_item if m else 0)


def _cross_kv(lp: Params, cfg: ArchConfig, enc_out: jnp.ndarray):
    B, S, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross_attn"]["wk"]) \
        .reshape(B, S, K, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross_attn"]["wv"]) \
        .reshape(B, S, K, hd)
    return k, v


def _dec_layer_full(lp, cfg, h, enc_out, positions, window: int = 0,
                    block_causal_skip: bool = False):
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = qkv_project(lp["self_attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                          H, K, hd, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True, window=window,
                          block_causal_skip=block_causal_skip)
    h = h + out_project(lp["self_attn"], o)
    xq = jnp.einsum("bsd,dh->bsh", rmsnorm(lp["ln_x"], h, cfg.norm_eps),
                    lp["cross_attn"]["wq"])
    B, S, _ = h.shape
    xq = xq.reshape(B, S, H, hd)
    ck, cv = _cross_kv(lp, cfg, enc_out)
    xo = chunked_attention(xq, ck, cv, causal=False)
    h = h + out_project(lp["cross_attn"], xo)
    h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
    return h, (k, v, ck, cv)


def _decoder(params, cfg, tokens, enc_out, *, window: int = 0,
             return_kv: bool = False, remat: bool = False,
             block_causal_skip: bool = False):
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]

    def body(h, lp):
        h, kv = _dec_layer_full(lp, cfg, h, enc_out, positions, window,
                                block_causal_skip)
        return h, kv if return_kv else None

    if remat:
        body = jax.checkpoint(body)
    x, kvs = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, kvs


def loss_fn(params: Params, cfg: ArchConfig, batch: Batch):
    enc_out = encode(params, cfg, batch["enc_frames"])
    h, _ = _decoder(params, cfg, batch["tokens"], enc_out, remat=True)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    return ce, {"ce": ce}


def prefill(params: Params, cfg: ArchConfig, batch: Batch, *, window: int = 0,
            max_len: int | None = None, block_causal_skip: bool = False):
    # EPD path: the E stage already ran `encode` elsewhere and ψ_EP shipped
    # its output — accept it via "enc_out" and skip re-encoding at P.
    if "enc_out" in batch and batch["enc_out"] is not None:
        enc_out = batch["enc_out"]
    else:
        enc_out = encode(params, cfg, batch["enc_frames"])
    B, S = batch["tokens"].shape
    h, (ks, vs, cks, cvs) = _decoder(params, cfg, batch["tokens"], enc_out,
                                     window=window, return_kv=True,
                                     block_causal_skip=block_causal_skip)
    logits = lm_head(params, cfg, h[:, -1])
    if window and window < S:
        W, start = window, S - window
        roll = start % window
        ks = jnp.roll(ks[:, :, start:], shift=roll, axis=2)
        vs = jnp.roll(vs[:, :, start:], shift=roll, axis=2)
    elif max_len is not None and max_len > S:
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
             "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, enc_len: int,
               window: int = 0, dtype=jnp.bfloat16) -> Batch:
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    W = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((L, batch, W, K, hd), dtype),
        "v": jnp.zeros((L, batch, W, K, hd), dtype),
        "cross_k": jnp.zeros((L, batch, enc_len, K, hd), dtype),
        "cross_v": jnp.zeros((L, batch, enc_len, K, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params: Params, cfg: ArchConfig, batch: Batch):
    cache = batch["cache"]
    token = batch["token"]
    pos = cache["pos"]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = params["embed"][token][:, None, :]
    W = cache["k"].shape[2]
    enc_len = cache["cross_k"].shape[2]

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        q, k, v = qkv_project(lp["self_attn"],
                              rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              H, K, hd, pos[:, None], cfg.rope_theta)
        kc, vc = cache_write(kc, vc, k[:, 0], v[:, 0], pos)
        o = decode_attention(q[:, 0], kc, vc, jnp.minimum(pos + 1, W))
        h = h + out_project(lp["self_attn"], o[:, None])
        xq = jnp.einsum("bsd,dh->bsh", rmsnorm(lp["ln_x"], h, cfg.norm_eps),
                        lp["cross_attn"]["wq"]).reshape(-1, 1, H, hd)
        B = h.shape[0]
        xo = decode_attention(xq[:, 0], ck, cv,
                              jnp.full((B,), enc_len, jnp.int32))
        h = h + out_project(lp["cross_attn"], xo[:, None])
        h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = lm_head(params, cfg, x[:, 0])
    new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    return logits, new_cache
