"""Attention: GQA projections, flash-style chunked attention (pure-jnp path),
decode attention over (optionally ring-buffer sliding-window) KV caches.

The pure-jnp chunked implementation is the portable path used for CPU smoke
tests and the dry-run lowering; on TPU the Pallas kernels in
``repro.kernels`` implement the same contract (``repro.kernels.*.ref`` are
thin wrappers over these functions).

Layouts:
  q:          (B, S, H, hd)
  k, v:       (B, S, K, hd)         K = kv heads, G = H // K
  kv cache:   (B, W, K, hd) per layer; stacked (L, B, W, K, hd) in the stack.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              dtype=jnp.bfloat16) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d, dtype),
    }


def qkv_project(p: Params, x: jnp.ndarray, n_heads: int, n_kv: int,
                head_dim: int, positions: jnp.ndarray | None,
                rope_theta: float):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,K,hd), rope applied if positions."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, n_heads, head_dim)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, n_kv, head_dim)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, n_kv, head_dim)
    if positions is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def out_project(p: Params, o: jnp.ndarray) -> jnp.ndarray:
    B, S, H, hd = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), p["wo"])


# ----------------------------------------------------------- full-seq attn
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      q_block: int = 512, kv_block: int = 512,
                      block_causal_skip: bool = False) -> jnp.ndarray:
    """Flash-style online-softmax attention, O(S * block) memory.

    q (B,Sq,H,hd); k,v (B,Sk,K,hd). GQA handled without materializing the
    repeated KV. ``window > 0`` = sliding-window causal attention.
    ``block_causal_skip`` unrolls the query-block loop in Python and slices
    KV to the causal prefix per block, halving HLO FLOPs for causal attention
    (beyond-paper §Perf optimization; default off = paper-faithful scan).
    """
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    if block_causal_skip and causal and window == 0:
        # the skip path unrolls in Python: cap the program at <=16x16 blocks
        q_block = max(q_block, -(-Sq // 16))
        kv_block = max(kv_block, -(-Sk // 16))
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    nq = -(-Sq // q_block)
    pad_q = nq * q_block - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nk = -(-Sk // kv_block)
    pad_k = nk * kv_block - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # (B, K, G, nq, qb, hd)
    qg = q.reshape(B, nq, q_block, K, G, hd).transpose(0, 3, 4, 1, 2, 5)
    kg = k.reshape(B, nk, kv_block, K, hd).transpose(0, 3, 1, 2, 4)
    vg = v.reshape(B, nk, kv_block, K, hd).transpose(0, 3, 1, 2, 4)

    q_pos_in_blk = jnp.arange(q_block)
    k_pos_in_blk = jnp.arange(kv_block)

    def q_block_body(qi: jnp.ndarray, qb: jnp.ndarray,
                     kv_prefix_blocks: int | None):
        """qb: (B,K,G,qb,hd); returns (B,K,G,qb,hd)."""
        q_pos = qi * q_block + q_pos_in_blk                       # (qb,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, kb, vb = inp                                       # (B,K,kb,hd)
            s = jnp.einsum("bkgqh,bkch->bkgqc", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            k_pos = kj * kv_block + k_pos_in_blk                   # (kb,)
            mask = k_pos[None, :] < Sk                             # pad mask
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window > 0:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_block, hd), jnp.float32)
        if kv_prefix_blocks is None:
            ks = jnp.arange(nk)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (ks, kg.transpose(2, 0, 1, 3, 4), vg.transpose(2, 0, 1, 3, 4)))
        else:
            m, l, acc = m0, l0, a0
            for j in range(kv_prefix_blocks):
                (m, l, acc), _ = kv_step(
                    (m, l, acc), (jnp.asarray(j), kg[:, :, j], vg[:, :, j]))
        return acc / jnp.maximum(l[..., None], 1e-30)

    if block_causal_skip and causal and window == 0:
        outs = []
        for i in range(nq):
            # causal prefix: kv blocks fully above the diagonal are skipped
            last_q = i * q_block + q_block - 1
            n_need = min(nk, last_q // kv_block + 1)
            outs.append(q_block_body(jnp.asarray(i), qg[:, :, :, i], n_need))
        o = jnp.stack(outs, axis=3)                                # (B,K,G,nq,qb,hd)
    else:
        o = jax.lax.map(
            lambda qi: q_block_body(qi, qg[:, :, :, qi], None), jnp.arange(nq))
        o = o.transpose(1, 2, 3, 0, 4, 5)                          # (B,K,G,nq,qb,hd)

    o = o.transpose(0, 3, 4, 1, 2, 5).reshape(B, nq * q_block, H, hd)
    return o[:, :Sq].astype(q.dtype)


# ------------------------------------------------------- chunked-prefill attn
def prefix_chunk_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           k_prev: jnp.ndarray, v_prev: jnp.ndarray,
                           prev_len: jnp.ndarray) -> jnp.ndarray:
    """One prefill chunk attending a cached prefix plus itself, causally.

    q (B, C, H, hd) and k/v (B, C, K, hd) are the current chunk (rope
    already applied at GLOBAL positions); k_prev/v_prev (B, Pmax, K, hd)
    is a fixed-width prefix buffer (e.g. gathered from pool blocks) whose
    first ``prev_len`` slots are valid — query i sits at global position
    ``prev_len + i``, so it sees the whole valid prefix and chunk keys
    j <= i. The fixed Pmax keeps the jitted shape identical across chunks
    (one trace for the whole prefill). Returns (B, C, H, hd)."""
    B, C, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    Pmax = k_prev.shape[1]
    kc = jnp.concatenate([k_prev, k], axis=1).astype(jnp.float32)
    vc = jnp.concatenate([v_prev, v], axis=1).astype(jnp.float32)
    qg = q.reshape(B, C, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bjkh->bkgqj", qg, kc) / math.sqrt(hd)
    j = jnp.arange(Pmax + C)
    i = jnp.arange(C)
    mask = jnp.where(j[None, :] < Pmax,
                     j[None, :] < prev_len,                 # valid prefix
                     (j[None, :] - Pmax) <= i[:, None])     # causal in-chunk
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkh->bqkgh", p, vc)
    return o.reshape(B, C, H, hd).astype(q.dtype)


# -------------------------------------------------------------- decode attn
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray) -> jnp.ndarray:
    """One-token attention over the cache.

    q: (B, H, hd); caches (B, W, K, hd); length (B,) = number of valid slots
    (for ring-buffer sliding windows the whole buffer is valid once wrapped,
    and ``length`` is clamped to W by the caller). Returns (B, H, hd).
    """
    B, W, K, hd = k_cache.shape
    H = q.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    valid = jnp.arange(W)[None] < length[:, None]                  # (B, W)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def cache_write(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                k_new: jnp.ndarray, v_new: jnp.ndarray,
                pos: jnp.ndarray):
    """Write one token's k/v (B, K, hd) at slot ``pos % W`` (ring buffer)."""
    W = k_cache.shape[1]
    slot = pos % W                                                  # (B,)
    b_idx = jnp.arange(k_cache.shape[0])
    k_cache = k_cache.at[b_idx, slot].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, slot].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache
