"""Zamba2-style hybrid stack: Mamba2 backbone + a SHARED attention block
every ``attn_every``-th layer.

Layer plan for n_layers=81, attn_every=6:
  13 groups of [5 mamba layers, 1 shared attn+MLP block] (78 layers)
  + 3 trailing mamba layers.
The attention block's weights are ONE set reused at every occurrence
(Zamba's signature weight sharing); only its KV cache is per-occurrence.

Caches: {"ssm"/"conv": grouped (G, 5, ...) + trailing (R, ...),
         "k"/"v": (G, B, W, K, hd), "pos": (B,)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (attn_init, cache_write, chunked_attention,
                                    decode_attention, out_project, qkv_project)
from repro.models.dense import chunked_loss, lm_head
from repro.models.layers import (Params, dense_init, embed_init, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init, stack_init)
from repro.models.mamba2 import (mamba2_decode_step, mamba2_forward,
                                 mamba2_init, mamba2_init_state)

Batch = dict


def plan(cfg: ArchConfig):
    """(n_groups, per_group_mamba, trailing_mamba)."""
    per = cfg.attn_every - 1
    groups = cfg.n_layers // cfg.attn_every
    trailing = cfg.n_layers - groups * cfg.attn_every
    return groups, per, trailing


def _mamba_layer_init(key, cfg: ArchConfig, dtype):
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "mix": mamba2_init(key, cfg, dtype)}


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    G, per, R = plan(cfg)
    ks = jax.random.split(key, 6)
    shared_k1, shared_k2 = jax.random.split(ks[2])
    p = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "groups": stack_init(
            ks[1], G,
            lambda k: stack_init(k, per,
                                 lambda k2: _mamba_layer_init(k2, cfg, dtype))),
        "shared_attn": {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(shared_k1, cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(shared_k2, cfg.d_model, cfg.d_ff, dtype),
        },
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab, dtype),
    }
    if R:
        p["trailing"] = stack_init(
            ks[4], R, lambda k: _mamba_layer_init(k, cfg, dtype))
    return p


def _mamba_sublayer(lp, cfg, x, state=None, decode=False):
    h = rmsnorm(lp["ln"], x, cfg.norm_eps)
    if decode:
        y, new_state = mamba2_decode_step(lp["mix"], cfg, h, state)
    else:
        y, new_state = mamba2_forward(lp["mix"], cfg, h,
                                      return_state=state is not None)
    return x + y, new_state


def _attn_block(sp, cfg, x, positions):
    q, k, v = qkv_project(sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps),
                          cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True)
    x = x + out_project(sp["attn"], o)
    x = x + mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
    return x, (k, v)


def _attn_block_decode(sp, cfg, x, kc, vc, pos):
    """x (B,1,d); kc/vc (B,W,K,hd)."""
    W = kc.shape[1]
    q, k, v = qkv_project(sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps),
                          cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          pos[:, None], cfg.rope_theta)
    kc, vc = cache_write(kc, vc, k[:, 0], v[:, 0], pos)
    o = decode_attention(q[:, 0], kc, vc, jnp.minimum(pos + 1, W))
    x = x + out_project(sp["attn"], o[:, None])
    x = x + mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps))
    return x, kc, vc


# --------------------------------------------------------------- full seq
def _full_seq(params, cfg, x, positions, want_state: bool,
              remat: bool = False):
    G, per, R = plan(cfg)
    sp = params["shared_attn"]

    def group_body(h, gp):
        def inner(h2, lp):
            h2, st = _mamba_sublayer(lp, cfg, h2,
                                     state=() if want_state else None)
            return h2, st
        h, states = jax.lax.scan(inner, h, gp)
        h, (k, v) = _attn_block(sp, cfg, h, positions)
        return h, (states, k, v)

    if remat:
        group_body = jax.checkpoint(group_body)
    x, (m_states, ks, vs) = jax.lax.scan(group_body, x, params["groups"])
    t_states = None
    if R:
        def inner(h2, lp):
            return _mamba_sublayer(lp, cfg, h2,
                                   state=() if want_state else None)
        x, t_states = jax.lax.scan(inner, x, params["trailing"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, (ks, vs), m_states, t_states


def loss_fn(params: Params, cfg: ArchConfig, batch: Batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    h, _, _, _ = _full_seq(params, cfg, x, positions, want_state=False,
                           remat=True)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    return ce, {"ce": ce}


def prefill(params: Params, cfg: ArchConfig, batch: Batch, *,
            max_len: int | None = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    h, (ks, vs), m_states, t_states = _full_seq(params, cfg, x, positions,
                                                want_state=True)
    logits = lm_head(params, cfg, h[:, -1])
    if max_len is not None and max_len > S:
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "ssm": m_states, "trailing_ssm": t_states,
             "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Batch:
    G, per, R = plan(cfg)
    ssm0, conv0 = mamba2_init_state(cfg, batch)

    def rep(t, n):
        return jnp.broadcast_to(t[None], (n,) + t.shape)

    def rep2(t):
        return jnp.broadcast_to(t[None, None], (G, per) + t.shape)

    W = min(window, max_len) if window else max_len
    kv_shape = (G, batch, W, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        "k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
        "ssm": (rep2(ssm0), rep2(conv0)),
        "trailing_ssm": (rep(ssm0, R), rep(conv0, R)) if R else None,
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    return cache


def decode_step(params: Params, cfg: ArchConfig, batch: Batch):
    cache = batch["cache"]
    token = batch["token"]
    pos = cache["pos"]
    G, per, R = plan(cfg)
    sp = params["shared_attn"]
    x = params["embed"][token][:, None, :]

    def group_body(h, xs):
        gp, g_ssm, kc, vc = xs

        def inner(h2, xs2):
            lp, st = xs2
            h2, st = _mamba_sublayer(lp, cfg, h2, state=st, decode=True)
            return h2, st
        h, g_ssm = jax.lax.scan(inner, h, (gp, g_ssm))
        h, kc, vc = _attn_block_decode(sp, cfg, h, kc, vc, pos)
        return h, (g_ssm, kc, vc)

    x, (m_states, ks, vs) = jax.lax.scan(
        group_body, x, (params["groups"], cache["ssm"], cache["k"], cache["v"]))
    t_states = cache["trailing_ssm"]
    if R:
        def inner(h2, xs2):
            lp, st = xs2
            return _mamba_sublayer(lp, cfg, h2, state=st, decode=True)
        x, t_states = jax.lax.scan(inner, x, (params["trailing"], t_states))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = lm_head(params, cfg, x[:, 0])
    new_cache = {"k": ks, "v": vs, "ssm": m_states, "trailing_ssm": t_states,
                 "pos": pos + 1}
    return logits, new_cache
