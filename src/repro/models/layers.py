"""Shared neural-net layers (pure-functional JAX).

Params are plain nested dicts of ``jnp.ndarray``. Layer stacks carry params
stacked along a leading layer axis and are driven by ``jax.lax.scan`` so the
HLO size is independent of depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


# --------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def stack_init(key, n: int, init_fn) -> Params:
    """Initialize ``n`` copies of a param tree and stack along axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


# ---------------------------------------------------------------- rmsnorm
def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


# ------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ swiglu
def mlp_init(key, d: int, f: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d, f, dtype),
        "wi_up": dense_init(k2, d, f, dtype),
        "wo": dense_init(k3, f, d, dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, p["wi_up"])
    # silu in the compute dtype: an fp32 round-trip here makes GSPMD
    # all-reduce fp32 activation grads in the TP backward (2x collective
    # bytes across every dense arch) — §Perf global iteration D1
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------- lm head
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean cross-entropy; logits (B,S,V) fp32-cast, labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - gold
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
