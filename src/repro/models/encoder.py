"""Generic non-causal transformer encoder.

Used for (a) the multimodal E-stage encoder that turns stub patch/frame
embeddings into multimodal tokens (the paper's ``v_t^e = E(i_m)``), and
(b) the whisper audio encoder. Patchify/conv frontends are stubbed per the
brief; the transformer itself is real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (attn_init, chunked_attention, out_project,
                                    qkv_project)
from repro.models.layers import (Params, mlp_apply, mlp_init, rmsnorm,
                                 rmsnorm_init, stack_init)


def enc_layer_init(key, d: int, heads: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    hd = d // heads
    return {
        "ln1": rmsnorm_init(d, dtype),
        "attn": attn_init(k1, d, heads, heads, hd, dtype),
        "ln2": rmsnorm_init(d, dtype),
        "mlp": mlp_init(k2, d, d_ff, dtype),
    }


def encoder_init(key, n_layers: int, d: int, heads: int, d_ff: int,
                 dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "layers": stack_init(k1, n_layers,
                             lambda k: enc_layer_init(k, d, heads, d_ff, dtype)),
        "ln_f": rmsnorm_init(d, dtype),
    }


def encoder_apply(p: Params, x: jnp.ndarray, *, heads: int,
                  rope_theta: float = 1e4, norm_eps: float = 1e-5,
                  segment: int = 0) -> jnp.ndarray:
    """x: (B, S, d) frame/patch embeddings -> (B, S, d) encoded.

    ``segment > 0`` makes attention BLOCK-DIAGONAL over groups of ``segment``
    tokens: each image patch / 30s audio window is encoded independently —
    faithful to per-patch ViTs and Whisper's windowing, and the property
    that makes the paper's IRP (intra-request parallelism) lossless:
    "since patches are encoded independently, they can be processed and
    transferred concurrently" (§3.2.2). It also kills the O(S^2) cross-
    segment attention that would otherwise dominate long-input encodes.
    """
    B, S, d = x.shape
    hd = d // heads
    pad = 0
    if segment and segment < S:
        pad = (-S) % segment
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        x = x.reshape(B * ((S + pad) // segment), segment, d)
    Sx = x.shape[1]
    positions = jnp.arange(Sx)[None, :]

    def body(h, lp):
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, norm_eps),
                              heads, heads, hd, positions, rope_theta)
        o = chunked_attention(q, k, v, causal=False)
        h = h + out_project(lp["attn"], o)
        h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], h, norm_eps))
        return h, None

    x, _ = jax.lax.scan(body, x, p["layers"])
    x = rmsnorm(p["ln_f"], x, norm_eps)
    if segment and segment < S + pad:
        x = x.reshape(B, S + pad, d)[:, :S]
    return x
