"""Decoder-only transformer stack: dense GQA, MoE, and VLM variants.

All stacks ``lax.scan`` over layers with stacked params; the KV cache is
``(L, B, W, K, hd)`` and decode threads it through the scan as xs/ys.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (attn_init, cache_write, chunked_attention,
                                    decode_attention, out_project,
                                    prefix_chunk_attention, qkv_project)
from repro.models.encoder import encoder_apply, encoder_init
from repro.models.layers import (Params, dense_init, embed_init, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init,
                                 softmax_xent, stack_init)
from repro.models.moe import moe_apply, moe_init

Batch = dict[str, Any]


def layer_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "layers": stack_init(ks[1], cfg.n_layers, lambda k: layer_init(k, cfg, dtype)),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    if cfg.modality is not None:
        m = cfg.modality
        p["mm_encoder"] = encoder_init(ks[3], m.enc_layers, m.enc_d_model,
                                       m.enc_heads, m.enc_d_ff, dtype)
        p["mm_proj"] = dense_init(ks[4], m.enc_d_model, cfg.d_model, dtype)
    return p


# ------------------------------------------------------------------ E stage
def encode_mm(params: Params, cfg: ArchConfig, mm_embeds: jnp.ndarray) -> jnp.ndarray:
    """The paper's E stage: stub patch/frame embeddings -> multimodal tokens.

    mm_embeds: (B, M, enc_d_model) -> (B, M, d_model). Patches are
    independent across the M dim, which is what IRP exploits.
    """
    m = cfg.modality
    h = encoder_apply(params["mm_encoder"], mm_embeds, heads=m.enc_heads,
                      norm_eps=cfg.norm_eps, segment=m.tokens_per_item)
    return jnp.einsum("bmd,de->bme", h, params["mm_proj"])


def embed_inputs(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                 mm_tokens: Optional[jnp.ndarray] = None,
                 mm_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = params["embed"][tokens]                                    # (B, S, d)
    if mm_tokens is not None:
        B = x.shape[0]
        b_idx = jnp.arange(B)[:, None]
        x = x.at[b_idx, mm_positions].set(mm_tokens.astype(x.dtype))
    return x


def _ffn(lp: Params, cfg: ArchConfig, h: jnp.ndarray):
    if cfg.moe is not None:
        if cfg.moe.use_shard_map:
            from repro.launch.context import current_mesh
            mesh = current_mesh()
            if mesh is not None and "model" in mesh.axis_names:
                M = mesh.shape["model"]
                D = mesh.devices.size // M
                B, S = h.shape[0], h.shape[1]
                ok = (cfg.moe.n_experts_padded % M == 0 and B % D == 0
                      and ((B // D) * S) % M == 0)
                if ok:
                    from repro.models.moe import moe_apply_shard_map
                    return moe_apply_shard_map(lp["moe"], h, cfg, mesh)
        return moe_apply(lp["moe"], h, cfg)
    return mlp_apply(lp["mlp"], h), jnp.float32(0.0)


# ------------------------------------------------------- full-seq forward
def forward(params: Params, cfg: ArchConfig, x: jnp.ndarray,
            positions: jnp.ndarray, *, window: int = 0, return_kv: bool = False,
            block_causal_skip: bool = False, remat: bool = False,
            backend: Any = None):
    """x: (B, S, d) -> (hidden (B,S,d), kv (L,B,S,K,hd) x2 | None, aux).

    ``backend`` is an :class:`~repro.kernels.registry.AttentionBackend`
    routing the attention call (None = the pure-jnp substrate, identical
    to the ``ref`` backend)."""
    attn = backend.prefill_attention if backend is not None else chunked_attention

    def body(h, lp):
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              positions, cfg.rope_theta)
        o = attn(q, k, v, causal=True, window=window,
                 block_causal_skip=block_causal_skip)
        h = h + out_project(lp["attn"], o)
        f, aux = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        ys = (k, v, aux) if return_kv else aux
        return h, ys

    if remat:
        # recompute layer activations in backward (standard at this scale)
        body = jax.checkpoint(body)
    x, ys = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_kv:
        ks, vs, aux = ys
        return x, (ks, vs), aux.mean()
    return x, None, ys.mean()


def lm_head(params: Params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("...d,dv->...v", h, w)


def _xent_sum(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).sum()


def chunked_loss(params: Params, cfg: ArchConfig, h: jnp.ndarray,
                 labels: jnp.ndarray, chunk: int = 1024) -> jnp.ndarray:
    """Cross-entropy without materializing (B, S, V) fp32 logits at once."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    hc = h[:, :n * chunk].reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hh, ll = xs
        return acc + _xent_sum(lm_head(params, cfg, hh), ll), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    if S - n * chunk:
        total = total + _xent_sum(lm_head(params, cfg, h[:, n * chunk:]),
                                  labels[:, n * chunk:])
    return total / (B * S)


# ----------------------------------------------------------------- entries
def loss_fn(params: Params, cfg: ArchConfig, batch: Batch, *,
            block_causal_skip: bool = False) -> tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    mm_tokens = None
    if cfg.modality is not None and "mm_embeds" in batch:
        mm_tokens = encode_mm(params, cfg, batch["mm_embeds"])
    x = embed_inputs(params, cfg, tokens, mm_tokens, batch.get("mm_positions"))
    positions = jnp.arange(S)[None, :]
    h, _, aux = forward(params, cfg, x, positions, window=cfg.sliding_window,
                        block_causal_skip=block_causal_skip, remat=True)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def prefill_core(params: Params, cfg: ArchConfig, batch: Batch, *,
                 window: int = 0, block_causal_skip: bool = False,
                 backend: Any = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared prefill forward: embed (raw ``mm_embeds`` are encoded here,
    pre-merged ``mm_tokens`` pass straight through), run the stack, return
    (last_logits (B, V), ks, vs (L, B, S, K, hd)). Every prefill variant —
    dense padded cache, EPD premerged, paged pool blocks — builds on this
    so their attention semantics cannot diverge."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    mm_tokens = batch.get("mm_tokens")
    if mm_tokens is None and cfg.modality is not None and "mm_embeds" in batch:
        mm_tokens = encode_mm(params, cfg, batch["mm_embeds"])
    x = embed_inputs(params, cfg, tokens, mm_tokens, batch.get("mm_positions"))
    positions = jnp.arange(S)[None, :]
    h, (ks, vs), _ = forward(params, cfg, x, positions, window=window,
                             return_kv=True,
                             block_causal_skip=block_causal_skip,
                             backend=backend)
    return lm_head(params, cfg, h[:, -1]), ks, vs


def prefill_chunk_core(params: Params, cfg: ArchConfig, batch: Batch, *,
                       backend: Any = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One position-offset chunk of a chunked prefill (paper §4 SLO story:
    a long prompt is prefilled chunk-by-chunk so decode never stalls a
    whole prompt's worth of compute behind it).

    batch:
      x          (B, C, d)        embedded chunk inputs (tail may be pad)
      positions  (B, C)  int32    GLOBAL positions t0 .. t0+C-1
      k_prev/v_prev (L, B, Pmax, K, hd) cached prefix KV (pad = garbage)
      prev_len   ()      int32    valid prefix tokens (== t0)
      last_idx   ()      int32    index of the final VALID chunk token

    Returns (logits of the last valid token (B, V), ks, vs (L, B, C, K, hd)).
    Logits are only meaningful on the final chunk; intermediate chunks use
    just the returned KV (scattered into the pool by the caller)."""
    x, positions = batch["x"], batch["positions"]
    prev_len = batch["prev_len"]
    attn = (backend.prefix_chunk_attention if backend is not None
            else prefix_chunk_attention)

    def body(h, xs):
        lp, kp, vp = xs
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              positions, cfg.rope_theta)
        o = attn(q, k, v, kp, vp, prev_len)
        h = h + out_project(lp["attn"], o)
        f, _ = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        return h, (k, v)

    h, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], batch["k_prev"], batch["v_prev"]))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    last = jax.lax.dynamic_index_in_dim(h, batch["last_idx"], axis=1,
                                        keepdims=False)           # (B, d)
    return lm_head(params, cfg, last), ks, vs


def prefill(params: Params, cfg: ArchConfig, batch: Batch, *,
            window: int = 0, max_len: int | None = None,
            block_causal_skip: bool = False,
            backend: Any = None) -> tuple[jnp.ndarray, Batch]:
    """Returns (last-token logits (B, V), kv cache dict).

    ``max_len`` adds decode headroom: the cache seq dim is padded to it so
    subsequent ``decode_step`` writes don't wrap over the prompt."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    eff_window = window or cfg.sliding_window
    logits, ks, vs = prefill_core(params, cfg, batch, window=eff_window,
                                  block_causal_skip=block_causal_skip,
                                  backend=backend)
    if eff_window and eff_window < S:
        # keep only the last ``window`` positions, ring-aligned
        W = eff_window
        start = S - W
        roll = start % W
        ks = jnp.roll(ks[:, :, start:], shift=roll, axis=2)
        vs = jnp.roll(vs[:, :, start:], shift=roll, axis=2)
    elif max_len is not None and max_len > ks.shape[2]:
        pad = max_len - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Batch:
    W = min(window, max_len) if window else max_len
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


# ------------------------------------------------------------ paged serving
def init_kv_pool(cfg: ArchConfig, n_blocks: int, block_size: int, *,
                 dtype=jnp.bfloat16) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Shared paged KV pool ``(L, n_blocks + 1, bs, K, hd)`` x2.

    One extra physical block is appended at index ``n_blocks``: it is the
    write target for inactive decode slots, so the batched step never needs
    a data-dependent skip (the trash block is simply never read with a
    meaningful length)."""
    shape = (cfg.n_layers, n_blocks + 1, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def pool_write_prefill(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                       ks: jnp.ndarray, vs: jnp.ndarray,
                       block_ids: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter a prompt's KV (L, 1, S, K, hd) into its pool blocks.

    Split from the forward pass so the serving engine only needs to hold
    its pool lock for this cheap scatter, not the whole prefill."""
    bs = k_pool.shape[2]
    nb = block_ids.shape[0]
    L, _, _, K, hd = k_pool.shape
    pad = nb * bs - ks.shape[2]
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    ks = ks[:, 0].reshape(L, nb, bs, K, hd).astype(k_pool.dtype)
    vs = vs[:, 0].reshape(L, nb, bs, K, hd).astype(v_pool.dtype)
    return k_pool.at[:, block_ids].set(ks), v_pool.at[:, block_ids].set(vs)


def paged_prefill(params: Params, cfg: ArchConfig, batch: Batch, *,
                  k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                  block_ids: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill one request (B=1) writing its KV straight into pool blocks.

    ψ_PD becomes a block-table handoff: the decode stage only needs the
    request's block ids + length, no padded dense cache is materialized or
    copied. ``batch`` may carry pre-merged ``mm_tokens``/``mm_positions``
    (EPD path: E ran elsewhere). ``block_ids``: (nb,) physical block ids
    with nb * block_size >= S. Returns (last_logits, k_pool', v_pool')."""
    if cfg.sliding_window:
        raise NotImplementedError(
            "paged serving has no ring-buffer layout for sliding-window "
            "archs; serve them with the dense decode mode")
    logits, ks, vs = prefill_core(params, cfg, batch)
    k_pool, v_pool = pool_write_prefill(k_pool, v_pool, ks, vs, block_ids)
    return logits, k_pool, v_pool


def paged_decode_step(params: Params, cfg: ArchConfig, batch: Batch, *,
                      force_ref: bool = False, backend: Any = None):
    """One batched autoregressive step over the shared paged KV pool.

    batch:
      tokens        (B,)  int32   last emitted token per decode slot
      positions     (B,)  int32   write position (== #cached tokens)
      active        (B,)  bool    slot occupancy mask
      block_tables  (B, max_blocks) int32 physical block ids (pad = trash)
      k_pool/v_pool (L, N, bs, K, hd)

    Inactive slots write into the reserved trash block (N-1) and attend a
    single trash token; their logits are discarded by the caller. Attention
    routes through ``backend.paged_attention`` when a backend is given
    (else the historical ``force_ref`` switch over the jit'd op). Returns
    (logits (B, V), next_tokens (B,), k_pool', v_pool')."""
    from repro.kernels.paged_attn import paged_decode_attention_op

    if backend is not None:
        paged_attn = backend.paged_attention
    else:
        paged_attn = (lambda q, kc, vc, tables, lengths:
                      paged_decode_attention_op(q, kc, vc, tables, lengths,
                                                force_ref=force_ref))
    tok, pos, active = batch["tokens"], batch["positions"], batch["active"]
    tables = batch["block_tables"]
    k_pool, v_pool = batch["k_pool"], batch["v_pool"]
    N, bs = k_pool.shape[1], k_pool.shape[2]
    B = tok.shape[0]
    b_idx = jnp.arange(B)
    phys = jnp.where(active, tables[b_idx, pos // bs], N - 1)      # (B,)
    slot = jnp.where(active, pos % bs, 0)
    lengths = jnp.where(active, pos + 1, 1)
    x = params["embed"][tok][:, None, :]                           # (B,1,d)

    def body(h, xs):
        lp, kc, vc = xs                                    # (N, bs, K, hd)
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              pos[:, None], cfg.rope_theta)
        kc = kc.at[phys, slot].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[phys, slot].set(v[:, 0].astype(vc.dtype))
        o = paged_attn(q[:, 0], kc, vc, tables, lengths)
        h = h + out_project(lp["attn"], o[:, None])
        f, _ = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], k_pool, v_pool))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = lm_head(params, cfg, h[:, 0])
    return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), ks, vs


# ------------------------------------------------------ token-packed step
def packed_step_core(params: Params, cfg: ArchConfig, batch: Batch, *,
                     backend: Any = None):
    """ONE token-packed forward over the shared paged pool: N decode
    slots and M prefill-chunk tokens execute as a single program.

    Every row of the flat ``(T,)`` arrays is one token — a decode slot's
    next token or one prompt token of an in-flight chunked prefill. Each
    token writes its KV into its sequence's pool blocks first, then
    attends its sequence's block table with ``length = position + 1``:
    for decode rows that is exactly ``paged_decode_step``'s math, and for
    chunk rows the scattered-own-chunk + pool-prefix read reproduces
    ``prefix_chunk_attention`` (same valid entries in the same order —
    masked-softmax padding is exact), so one attention primitive serves
    the whole batch. Chunk rows of the SAME sequence may share one call:
    per layer, every row's KV is scattered before any row attends, and
    per-row lengths causally mask the later rows.

    batch (all (T,) unless noted):
      token_ids   int32   last emitted token (decode rows; else 0)
      x_prefill   (T, d)  pre-embedded prompt inputs (chunk rows; else 0)
      is_prefill  bool    row class selector
      positions   int32   global sequence position of the token
      write_block int32   pool block receiving this token's KV (pad=trash)
      write_slot  int32   slot within that block
      tables      (T, max_blocks) int32  the row's sequence block table
      lengths     int32   positions + 1 for live rows, 1 for pad rows
      temperature/top_p f32, seeds uint32, sample_pos int32  per-row
                  sampling state (the sampled head runs for every row;
                  callers read only the rows they planned)
      k_pool/v_pool (L, N, bs, K, hd)

    Returns (logits (T, V), next_tokens (T,), k_pool', v_pool')."""
    if backend is not None:
        paged_attn = backend.paged_attention
    else:
        from repro.kernels.paged_attn import paged_decode_attention_op
        paged_attn = partial(paged_decode_attention_op, force_ref=True)

    tok, positions = batch["token_ids"], batch["positions"]
    wb, ws = batch["write_block"], batch["write_slot"]
    tables, lengths = batch["tables"], batch["lengths"]
    k_pool, v_pool = batch["k_pool"], batch["v_pool"]
    x = jnp.where(batch["is_prefill"][:, None], batch["x_prefill"],
                  params["embed"][tok])[:, None, :]               # (T,1,d)

    def body(h, xs):
        lp, kc, vc = xs                                    # (N, bs, K, hd)
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              positions[:, None], cfg.rope_theta)
        kc = kc.at[wb, ws].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[wb, ws].set(v[:, 0].astype(vc.dtype))
        o = paged_attn(q[:, 0], kc, vc, tables, lengths)
        h = h + out_project(lp["attn"], o[:, None])
        f, _ = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], k_pool, v_pool))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = lm_head(params, cfg, h[:, 0])                        # (T, V)
    nxt = sample_tokens(logits, batch["temperature"], batch["top_p"],
                        batch["seeds"], batch["sample_pos"])
    return logits, nxt, ks, vs


# ------------------------------------------------------------ sampling head
def sample_tokens(logits: jnp.ndarray, temperature: jnp.ndarray,
                  top_p: jnp.ndarray, seeds: jnp.ndarray,
                  sample_pos: jnp.ndarray) -> jnp.ndarray:
    """Per-slot sampled decode head: greedy where ``temperature == 0``
    (bit-identical to argmax), nucleus (top-p) sampling elsewhere.

    logits (B, V); temperature/top_p (B,) f32; seeds (B,) uint32 is the
    per-request PRNG seed; sample_pos (B,) int32 is the number of tokens
    generated so far. The key is ``fold_in(PRNGKey(seed), sample_pos)``,
    a pure function of (seed, token index) — so a preempted request
    deterministically replays its already-streamed prefix."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def nucleus(l, t, p, seed, pos):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        scaled = l / jnp.maximum(t, 1e-6)
        order = jnp.argsort(-scaled)
        probs = jax.nn.softmax(scaled[order])
        cum = jnp.cumsum(probs)
        # minimal prefix whose mass reaches top_p (top-1 always kept)
        keep = (cum - probs) < p
        masked = jnp.where(keep, scaled[order], -jnp.inf)
        return order[jax.random.categorical(key, masked)].astype(jnp.int32)

    sampled = jax.vmap(nucleus)(logits, temperature, top_p, seeds,
                                sample_pos)
    return jnp.where(temperature > 0, sampled, greedy)


def decode_step(params: Params, cfg: ArchConfig, batch: Batch, *,
                backend: Any = None) -> tuple[jnp.ndarray, Batch]:
    """One autoregressive step. batch: {"token": (B,), "cache": {...}}."""
    cache = batch["cache"]
    token = batch["token"]
    pos = cache["pos"]                                             # (B,)
    B = token.shape[0]
    W = cache["k"].shape[2]
    x = params["embed"][token][:, None, :]                         # (B,1,d)
    attn = (backend.decode_attention if backend is not None
            else decode_attention)

    def body(h, xs):
        lp, kc, vc = xs
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              pos[:, None], cfg.rope_theta)
        kc, vc = cache_write(kc, vc, k[:, 0], v[:, 0], pos)
        length = jnp.minimum(pos + 1, W)
        o = attn(q[:, 0], kc, vc, length)                          # (B,H,hd)
        h = h + out_project(lp["attn"], o[:, None])
        f, _ = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = lm_head(params, cfg, h[:, 0])
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache
