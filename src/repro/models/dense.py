"""Decoder-only transformer stack: dense GQA, MoE, and VLM variants.

All stacks ``lax.scan`` over layers with stacked params; the KV cache is
``(L, B, W, K, hd)`` and decode threads it through the scan as xs/ys.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (attn_init, cache_write, chunked_attention,
                                    decode_attention, out_project, qkv_project)
from repro.models.encoder import encoder_apply, encoder_init
from repro.models.layers import (Params, dense_init, embed_init, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init,
                                 softmax_xent, stack_init)
from repro.models.moe import moe_apply, moe_init

Batch = dict[str, Any]


def layer_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "layers": stack_init(ks[1], cfg.n_layers, lambda k: layer_init(k, cfg, dtype)),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    if cfg.modality is not None:
        m = cfg.modality
        p["mm_encoder"] = encoder_init(ks[3], m.enc_layers, m.enc_d_model,
                                       m.enc_heads, m.enc_d_ff, dtype)
        p["mm_proj"] = dense_init(ks[4], m.enc_d_model, cfg.d_model, dtype)
    return p


# ------------------------------------------------------------------ E stage
def encode_mm(params: Params, cfg: ArchConfig, mm_embeds: jnp.ndarray) -> jnp.ndarray:
    """The paper's E stage: stub patch/frame embeddings -> multimodal tokens.

    mm_embeds: (B, M, enc_d_model) -> (B, M, d_model). Patches are
    independent across the M dim, which is what IRP exploits.
    """
    m = cfg.modality
    h = encoder_apply(params["mm_encoder"], mm_embeds, heads=m.enc_heads,
                      norm_eps=cfg.norm_eps, segment=m.tokens_per_item)
    return jnp.einsum("bmd,de->bme", h, params["mm_proj"])


def embed_inputs(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                 mm_tokens: Optional[jnp.ndarray] = None,
                 mm_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = params["embed"][tokens]                                    # (B, S, d)
    if mm_tokens is not None:
        B = x.shape[0]
        b_idx = jnp.arange(B)[:, None]
        x = x.at[b_idx, mm_positions].set(mm_tokens.astype(x.dtype))
    return x


def _ffn(lp: Params, cfg: ArchConfig, h: jnp.ndarray):
    if cfg.moe is not None:
        if cfg.moe.use_shard_map:
            from repro.launch.context import current_mesh
            mesh = current_mesh()
            if mesh is not None and "model" in mesh.axis_names:
                M = mesh.shape["model"]
                D = mesh.devices.size // M
                B, S = h.shape[0], h.shape[1]
                ok = (cfg.moe.n_experts_padded % M == 0 and B % D == 0
                      and ((B // D) * S) % M == 0)
                if ok:
                    from repro.models.moe import moe_apply_shard_map
                    return moe_apply_shard_map(lp["moe"], h, cfg, mesh)
        return moe_apply(lp["moe"], h, cfg)
    return mlp_apply(lp["mlp"], h), jnp.float32(0.0)


# ------------------------------------------------------- full-seq forward
def forward(params: Params, cfg: ArchConfig, x: jnp.ndarray,
            positions: jnp.ndarray, *, window: int = 0, return_kv: bool = False,
            block_causal_skip: bool = False, remat: bool = False):
    """x: (B, S, d) -> (hidden (B,S,d), kv (L,B,S,K,hd) x2 | None, aux)."""

    def body(h, lp):
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              positions, cfg.rope_theta)
        o = chunked_attention(q, k, v, causal=True, window=window,
                              block_causal_skip=block_causal_skip)
        h = h + out_project(lp["attn"], o)
        f, aux = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        ys = (k, v, aux) if return_kv else aux
        return h, ys

    if remat:
        # recompute layer activations in backward (standard at this scale)
        body = jax.checkpoint(body)
    x, ys = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_kv:
        ks, vs, aux = ys
        return x, (ks, vs), aux.mean()
    return x, None, ys.mean()


def lm_head(params: Params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("...d,dv->...v", h, w)


def _xent_sum(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).sum()


def chunked_loss(params: Params, cfg: ArchConfig, h: jnp.ndarray,
                 labels: jnp.ndarray, chunk: int = 1024) -> jnp.ndarray:
    """Cross-entropy without materializing (B, S, V) fp32 logits at once."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    hc = h[:, :n * chunk].reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hh, ll = xs
        return acc + _xent_sum(lm_head(params, cfg, hh), ll), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    if S - n * chunk:
        total = total + _xent_sum(lm_head(params, cfg, h[:, n * chunk:]),
                                  labels[:, n * chunk:])
    return total / (B * S)


# ----------------------------------------------------------------- entries
def loss_fn(params: Params, cfg: ArchConfig, batch: Batch, *,
            block_causal_skip: bool = False) -> tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    mm_tokens = None
    if cfg.modality is not None and "mm_embeds" in batch:
        mm_tokens = encode_mm(params, cfg, batch["mm_embeds"])
    x = embed_inputs(params, cfg, tokens, mm_tokens, batch.get("mm_positions"))
    positions = jnp.arange(S)[None, :]
    h, _, aux = forward(params, cfg, x, positions, window=cfg.sliding_window,
                        block_causal_skip=block_causal_skip, remat=True)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def prefill(params: Params, cfg: ArchConfig, batch: Batch, *,
            window: int = 0, max_len: int | None = None,
            block_causal_skip: bool = False) -> tuple[jnp.ndarray, Batch]:
    """Returns (last-token logits (B, V), kv cache dict).

    ``max_len`` adds decode headroom: the cache seq dim is padded to it so
    subsequent ``decode_step`` writes don't wrap over the prompt."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    mm_tokens = None
    if cfg.modality is not None and "mm_embeds" in batch:
        mm_tokens = encode_mm(params, cfg, batch["mm_embeds"])
    x = embed_inputs(params, cfg, tokens, mm_tokens, batch.get("mm_positions"))
    positions = jnp.arange(S)[None, :]
    eff_window = window or cfg.sliding_window
    h, (ks, vs), _ = forward(params, cfg, x, positions, window=eff_window,
                             return_kv=True,
                             block_causal_skip=block_causal_skip)
    logits = lm_head(params, cfg, h[:, -1])
    if eff_window and eff_window < S:
        # keep only the last ``window`` positions, ring-aligned
        W = eff_window
        start = S - W
        roll = start % W
        ks = jnp.roll(ks[:, :, start:], shift=roll, axis=2)
        vs = jnp.roll(vs[:, :, start:], shift=roll, axis=2)
    elif max_len is not None and max_len > ks.shape[2]:
        pad = max_len - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Batch:
    W = min(window, max_len) if window else max_len
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(params: Params, cfg: ArchConfig, batch: Batch
                ) -> tuple[jnp.ndarray, Batch]:
    """One autoregressive step. batch: {"token": (B,), "cache": {...}}."""
    cache = batch["cache"]
    token = batch["token"]
    pos = cache["pos"]                                             # (B,)
    B = token.shape[0]
    W = cache["k"].shape[2]
    x = params["embed"][token][:, None, :]                         # (B,1,d)

    def body(h, xs):
        lp, kc, vc = xs
        q, k, v = qkv_project(lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
                              cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                              pos[:, None], cfg.rope_theta)
        kc, vc = cache_write(kc, vc, k[:, 0], v[:, 0], pos)
        length = jnp.minimum(pos + 1, W)
        o = decode_attention(q[:, 0], kc, vc, length)              # (B,H,hd)
        h = h + out_project(lp["attn"], o[:, None])
        f, _ = _ffn(lp, cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = h + f
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = lm_head(params, cfg, h[:, 0])
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache
