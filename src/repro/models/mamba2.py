"""Mamba2 (SSD) block: chunked scan for train/prefill, O(1) recurrent decode.

Layout conventions:
  d_inner = expand * d_model;  H = d_inner // head_dim;  P = head_dim;
  N = d_state; n_groups = 1 (B/C shared across heads).
State cache per layer: ssm (B, H, P, N) fp32, conv (B, d_conv-1, C_conv)
with C_conv = d_inner + 2N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMSpec
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


def mamba2_dims(cfg: ArchConfig):
    s = cfg.ssm or SSMSpec()
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return s, d_inner, H, s.head_dim, s.d_state


def mamba2_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    s, di, H, P, N = mamba2_dims(cfg)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    c_conv = di + 2 * N
    return {
        "in_proj": dense_init(k1, d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, c_conv), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((c_conv,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(k3, di, d, dtype),
    }


def _split_proj(p: Params, x: jnp.ndarray, di: int, N: int, H: int):
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt


def _conv_full(p: Params, xbc: jnp.ndarray, d_conv: int) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, C)."""
    pad = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * p["conv_w"][i]
              for i in range(d_conv))
    return jax.nn.silu((out + p["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(xs: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                Bmat: jnp.ndarray, Cmat: jnp.ndarray, chunk: int,
                state0: jnp.ndarray | None = None):
    """Core chunked SSD scan (shared oracle with the Pallas kernel).

    xs (B,S,H,P); dt,a (B,S,H); B/C (B,S,N) -> (y (B,S,H,P) fp32,
    final state (B,H,P,N) fp32). ``a = dt * A`` (negative)."""
    B, S, H, P = xs.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    while S % Q:  # largest divisor of S not exceeding the chunk setting
        Q -= 1
    nc = S // Q

    xs_c = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    B_c = Bmat.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cmat.reshape(B, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nc, Q, H)
    a_c = a.reshape(B, nc, Q, H)

    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))

    def chunk_step(state, inp):
        xq, bq, cq, dtq, aq = inp                                  # (B,Q,...)
        cum = jnp.cumsum(aq, axis=1)                               # (B,Q,H)
        # inter-chunk: contribution of incoming state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, state) \
            * jnp.exp(cum)[..., None]                              # decay to t
        # intra-chunk
        scores = jnp.einsum("bqn,bkn->bqk", cq, bq)                # (B,Q,Q)
        diff = cum[:, :, None, :] - cum[:, None, :, :]             # (B,Q,K,H)
        diff = jnp.where(tri[None, :, :, None], diff, -jnp.inf)    # mask k>q
        m = scores[..., None] * jnp.exp(diff) * dtq[:, None, :, :]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", m, xq)
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)                       # (B,Q,H)
        contrib = jnp.einsum("bkh,bkn,bkhp->bhpn", tail * dtq, bq, xq)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + contrib
        return state, y_inter + y_intra

    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs_t = (xs_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
            C_c.transpose(1, 0, 2, 3), dt_c.transpose(1, 0, 2, 3),
            a_c.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(chunk_step, state0, xs_t)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, state


def mamba2_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                   return_state: bool = False):
    """Full-sequence chunked SSD. x: (B, S, d) -> (y (B, S, d), final_state?)."""
    s, di, H, P, N = mamba2_dims(cfg)
    B, S, d = x.shape

    z, xbc, dt = _split_proj(p, x, di, N, H)
    xbc = _conv_full(p, xbc, s.d_conv)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bmat = xbc[..., di:di + N]                                     # (B,S,N)
    Cmat = xbc[..., di + N:]                                       # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    a = dt * A                                                     # (B,S,H) <0

    y, state = ssd_chunked(xs, dt, a, Bmat, Cmat, s.chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    if return_state:
        conv_state = xbc_raw_tail(p, x, di, N, H, s.d_conv)
        return out, (state, conv_state)
    return out, None


def xbc_raw_tail(p: Params, x: jnp.ndarray, di: int, N: int, H: int,
                 d_conv: int) -> jnp.ndarray:
    """Last d_conv-1 pre-conv xbc inputs (for the decode conv state)."""
    _, xbc, _ = _split_proj(p, x[:, -(d_conv - 1):], di, N, H)
    return xbc.astype(jnp.bfloat16)


def mamba2_init_state(cfg: ArchConfig, batch: int):
    s, di, H, P, N = mamba2_dims(cfg)
    return (jnp.zeros((batch, H, P, N), jnp.float32),
            jnp.zeros((batch, s.d_conv - 1, di + 2 * N), jnp.bfloat16))


def mamba2_decode_step(p: Params, cfg: ArchConfig, x: jnp.ndarray, state):
    """x: (B, 1, d); state = (ssm (B,H,P,N), conv (B,d_conv-1,C))."""
    s, di, H, P, N = mamba2_dims(cfg)
    ssm, conv = state
    B = x.shape[0]
    z, xbc, dt = _split_proj(p, x, di, N, H)                       # (B,1,...)
    xbc = xbc[:, 0]
    # conv over the stored tail + current input
    hist = jnp.concatenate([conv.astype(xbc.dtype), xbc[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_new = hist[:, 1:]
    xbc_t = jax.nn.silu(conv_out.astype(jnp.float32))              # (B,C)
    xt = xbc_t[:, :di].reshape(B, H, P)
    bt = xbc_t[:, di:di + N]
    ct = xbc_t[:, di + N:]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * A)                                      # (B,H)
    ssm = ssm * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt_t, bt, xt)
    y = jnp.einsum("bn,bhpn->bhp", ct, ssm)                        # (B,H,P)
    y = y + p["D"][None, :, None] * xt
    y = y.reshape(B, di).astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None]       # (B,1,d)
    return out, (ssm, conv_new)
