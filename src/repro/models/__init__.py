from repro.models.api import (Model, build_model, input_specs,
                              make_concrete_batch, mm_token_budget,
                              uses_sliding_window_variant)

__all__ = ["Model", "build_model", "input_specs", "make_concrete_batch",
           "mm_token_budget", "uses_sliding_window_variant"]
