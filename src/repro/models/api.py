"""Model facade: one uniform interface over all architecture families.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions:

  init(key)                      -> params
  loss_fn(params, batch)         -> (loss, metrics)          [train]
  prefill(params, batch)         -> (last_logits, cache)     [P stage]
  decode_step(params, batch)     -> (logits, new_cache)      [D stage]
  init_cache(batch, max_len)     -> cache pytree
  encode(params, mm_embeds)      -> mm tokens                [E stage; mm archs]

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
input of the step function the shape exercises — the dry-run lowers against
these with zero device allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import dense, encdec, hybrid, rwkv_stack

Batch = dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    encode: Optional[Callable] = None
    # paged serving (dense-family only): prefill writes KV straight into a
    # shared block pool; decode is one batched step over block tables.
    paged_decode_step: Optional[Callable] = None
    init_kv_pool: Optional[Callable] = None

    @property
    def name(self) -> str:
        return self.cfg.name


def build_model(cfg: ArchConfig, *, block_causal_skip: bool = False) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=partial(dense.init_params, cfg=cfg),
            loss_fn=partial(dense.loss_fn, cfg=cfg,
                            block_causal_skip=block_causal_skip),
            prefill=partial(dense.prefill, cfg=cfg,
                            block_causal_skip=block_causal_skip),
            decode_step=partial(dense.decode_step, cfg=cfg),
            init_cache=partial(dense.init_cache, cfg),
            encode=((lambda params, mm_embeds:
                     dense.encode_mm(params, cfg, mm_embeds))
                    if cfg.modality is not None else None),
            paged_decode_step=partial(dense.paged_decode_step, cfg=cfg),
            init_kv_pool=partial(dense.init_kv_pool, cfg),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=partial(hybrid.init_params, cfg=cfg),
            loss_fn=partial(hybrid.loss_fn, cfg=cfg),
            prefill=partial(hybrid.prefill, cfg=cfg),
            decode_step=partial(hybrid.decode_step, cfg=cfg),
            init_cache=partial(hybrid.init_cache, cfg),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=partial(rwkv_stack.init_params, cfg=cfg),
            loss_fn=partial(rwkv_stack.loss_fn, cfg=cfg),
            prefill=partial(rwkv_stack.prefill, cfg=cfg),
            decode_step=partial(rwkv_stack.decode_step, cfg=cfg),
            init_cache=partial(rwkv_stack.init_cache, cfg),
        )
    if fam == "audio":
        return Model(
            cfg=cfg,
            init=partial(encdec.init_params, cfg=cfg),
            loss_fn=partial(encdec.loss_fn, cfg=cfg),
            prefill=partial(encdec.prefill, cfg=cfg,
                            block_causal_skip=block_causal_skip),
            decode_step=partial(encdec.decode_step, cfg=cfg),
            init_cache=partial(encdec.init_cache, cfg),
            encode=lambda params, frames: encdec.encode(params, cfg, frames),
        )
    raise ValueError(f"unknown family {fam}")


# -------------------------------------------------------------- input specs
def uses_sliding_window_variant(cfg: ArchConfig, shape: InputShape) -> bool:
    """long_500k on full-attention archs runs the sliding-window variant."""
    if shape.name != "long_500k":
        return False
    return cfg.family in ("dense", "moe", "vlm", "audio")


def mm_token_budget(cfg: ArchConfig, seq_len: int) -> int:
    """# multimodal token positions inside a seq (VLM/ultravox batches)."""
    m = cfg.modality
    if m is None:
        return 0
    return min(seq_len // 2, 2 * m.tokens_per_item)


def input_specs(cfg: ArchConfig, shape: InputShape, *,
                dtype=jnp.bfloat16) -> Batch:
    """ShapeDtypeStruct stand-ins for the step the shape exercises."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def mm_specs() -> Batch:
        m = cfg.modality
        M = mm_token_budget(cfg, S)
        return {"mm_embeds": sds((B, M, m.enc_d_model), dtype),
                "mm_positions": sds((B, M), i32)}

    if shape.mode == "train":
        batch: Batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "audio":
            batch["enc_frames"] = sds((B, S, cfg.d_model), dtype)
        elif cfg.modality is not None:
            batch.update(mm_specs())
        return batch

    if shape.mode == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "audio":
            batch["enc_frames"] = sds((B, S, cfg.d_model), dtype)
        elif cfg.modality is not None:
            batch.update(mm_specs())
        return batch

    # decode: one token + a filled cache of length S
    window = cfg.long_context_window if uses_sliding_window_variant(cfg, shape) else 0
    model = build_model(cfg)
    kwargs = dict(window=window)
    if cfg.family == "audio":
        kwargs["enc_len"] = min(S, 30_000)  # cross-attn cache (frames)
        if shape.name == "long_500k":
            kwargs["enc_len"] = S
    cache = jax.eval_shape(lambda: model.init_cache(B, S, **kwargs))
    return {"token": sds((B,), i32), "cache": cache}


def make_concrete_batch(cfg: ArchConfig, shape: InputShape, key,
                        dtype=jnp.bfloat16) -> Batch:
    """Materialize a random batch matching ``input_specs`` (smoke tests)."""
    specs = input_specs(cfg, shape, dtype=dtype)

    def fill(path, s):
        name = path[-1].key if path else ""
        k = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        if s.dtype == jnp.int32:
            if name == "mm_positions":
                M = s.shape[-1]
                base = jnp.arange(M, dtype=jnp.int32)[None]
                return jnp.broadcast_to(1 + base, s.shape)
            hi = cfg.vocab if name in ("tokens", "labels", "token") else 2
            return jax.random.randint(k, s.shape, 0, hi, jnp.int32)
        return jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.1

    return jax.tree_util.tree_map_with_path(fill, specs)
