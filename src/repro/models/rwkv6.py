"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Per-head WKV state is (B, H, P, P) with P = head_dim. Train/prefill runs a
time scan (the Pallas kernel in ``repro.kernels.rwkv6_scan`` is the TPU fast
path); decode is a single recurrence step.
State cache per layer: (wkv (B,H,P,P) fp32, shift_tm (B,d), shift_cm (B,d)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RWKVSpec
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init

DECAY_LORA = 64


def rwkv6_dims(cfg: ArchConfig):
    spec = cfg.rwkv or RWKVSpec()
    H = spec.n_heads(cfg.d_model)
    return spec, H, spec.head_dim


def rwkv6_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    spec, H, P = rwkv6_dims(cfg)
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),     # base decay (log-log)
        "w_lora_a": dense_init(ks[6], d, DECAY_LORA, jnp.float32),
        "w_lora_b": (jax.random.normal(ks[7], (DECAY_LORA, d), jnp.float32)
                     * 0.01),
        "u": jnp.zeros((H, P), jnp.float32),         # bonus for current token
        "ln_x": rmsnorm_init(d, dtype),
        # channel-mix
        "mu_cm": (jax.random.uniform(ks[8], (2, d), jnp.float32)).astype(dtype),
        "ck": dense_init(ks[9], d, cfg.d_ff, dtype),
        "cv": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, d, dtype),
        "cr": dense_init(jax.random.fold_in(key, 98), d, d, dtype),
    }


def _shift(x: jnp.ndarray, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token shift: x_{t-1}; prev supplies the t=-1 row for decode chaining."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _decay(p: Params, xw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent per-channel decay w_t in (0,1). xw: (..., d)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w0"] + lora))


def _time_mix_inputs(p: Params, x: jnp.ndarray, shifted: jnp.ndarray):
    mu = p["mu"].astype(x.dtype)                                   # (5, d)
    mix = [x + mu[i] * (shifted - x) for i in range(5)]
    r = mix[0] @ p["wr"]
    k = mix[1] @ p["wk"]
    v = mix[2] @ p["wv"]
    g = jax.nn.silu((mix[3] @ p["wg"]).astype(jnp.float32)).astype(x.dtype)
    w = _decay(p, mix[4])
    return r, k, v, g, w


def wkv_scan(r, k, v, w, u, state0):
    """Sequential WKV. r,k,v: (B,S,H,P); w: (B,S,H,P); u: (H,P).
    Returns y (B,S,H,P) fp32 and final state (B,H,P,P).
    state[b,h,i,j] accumulates k_i ⊗ v_j."""
    def step(state, inp):
        rt, kt, vt, wt = inp                                       # (B,H,P)
        kv = kt[..., :, None] * vt[..., None, :]                   # (B,H,P,P)
        y = jnp.einsum("bhi,bhij->bhj", rt, state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32)
               for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state


def rwkv6_time_mix(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                   state0=None, prev_shift=None, return_state=False):
    B, S, d = x.shape
    spec, H, P = rwkv6_dims(cfg)
    shifted = _shift(x, prev_shift)
    r, k, v, g, w = _time_mix_inputs(p, x, shifted)
    rh = r.reshape(B, S, H, P)
    kh = k.reshape(B, S, H, P)
    vh = v.reshape(B, S, H, P)
    wh = w.reshape(B, S, H, P)
    if state0 is None:
        state0 = jnp.zeros((B, H, P, P), jnp.float32)
    y, state = wkv_scan(rh, kh, vh, wh, p["u"], state0)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(p["ln_x"], y, cfg.norm_eps) * g
    out = y @ p["wo"]
    if return_state:
        return out, (state, x[:, -1])
    return out, None


def rwkv6_channel_mix(p: Params, x: jnp.ndarray, prev_shift=None,
                      return_state=False):
    shifted = _shift(x, prev_shift)
    mu = p["mu_cm"].astype(x.dtype)
    xk = x + mu[0] * (shifted - x)
    xr = x + mu[1] * (shifted - x)
    k = jnp.square(jax.nn.relu((xk @ p["ck"]).astype(jnp.float32))).astype(x.dtype)
    out = jax.nn.sigmoid((xr @ p["cr"]).astype(jnp.float32)).astype(x.dtype) \
        * (k @ p["cv"])
    if return_state:
        return out, x[:, -1]
    return out, None


# ------------------------------------------------------------ block + state
def rwkv6_block_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    k1 = jax.random.split(key, 1)[0]
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "tm": rwkv6_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }


def rwkv6_block(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                state=None, return_state=False):
    """state = (wkv (B,H,P,P), shift_tm (B,d), shift_cm (B,d)) or None."""
    wkv0, sh_tm, sh_cm = state if state is not None else (None, None, None)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    tm, tm_state = rwkv6_time_mix(p["tm"], cfg, h, wkv0, sh_tm, return_state)
    x = x + tm
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    cm, cm_state = rwkv6_channel_mix(p["tm"], h2, sh_cm, return_state)
    x = x + cm
    if return_state:
        wkv, tm_shift = tm_state
        return x, (wkv, tm_shift, cm_state)
    return x, None


def rwkv6_init_state(cfg: ArchConfig, batch: int):
    spec, H, P = rwkv6_dims(cfg)
    d = cfg.d_model
    return (jnp.zeros((batch, H, P, P), jnp.float32),
            jnp.zeros((batch, d), jnp.bfloat16),
            jnp.zeros((batch, d), jnp.bfloat16))
