"""Mixture-of-Experts FFN: top-k routing with sort-based dispatch.

Dispatch is the TPU-friendly sorted-scatter formulation (MegaBlocks/MaxText
style): token->expert assignments are sorted, each token lands at a
(expert, slot) coordinate within a fixed per-expert capacity ``C``, expert
FFNs run as one grouped einsum over (E, C, d), and results scatter back with
router weights. Tokens beyond capacity are dropped (standard capacity-factor
semantics). Under expert-parallel sharding the (E, C, d) buffer is sharded on
the expert dim, which GSPMD turns into an all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, dense_init


def _constrain(x, axes: tuple):
    """Best-effort sharding constraint (no-op without an active mesh)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:
        return x


def moe_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    assert cfg.moe is not None
    E, d, f = cfg.moe.n_experts_padded, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def ginit(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
            jnp.float32(fan_in))).astype(dtype)

    return {
        "router": dense_init(k1, d, cfg.moe.n_experts, jnp.float32),
        "wi_gate": ginit(k2, (E, d, f), d),
        "wi_up": ginit(k3, (E, d, f), d),
        "wo": ginit(k4, (E, f, d), f),
    }


def _dispatch_one_group(xt, top_i, top_w, E: int, k: int, cap: int):
    """Sort-based dispatch WITHIN one data-parallel group.

    xt (T, d); top_i/top_w (T, k). Returns (buf (E, cap, d), dest, keep,
    src_tok, w_sorted) for the combine step."""
    T, d = xt.shape
    Tk = T * k
    flat_e = top_i.reshape(Tk)
    flat_w = top_w.reshape(Tk)
    order = jnp.argsort(flat_e, stable=True)                       # (Tk,)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                        # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(Tk) - starts[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)     # OOB drops
    src_tok = order // k
    buf = jnp.zeros((E * cap, d), xt.dtype).at[dest].set(
        xt[src_tok], mode="drop").reshape(E, cap, d)
    return buf, dest, keep, src_tok, flat_w[order].astype(xt.dtype)


def _combine_one_group(out_flat, dest, keep, src_tok, w_sorted, T: int,
                       d: int):
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.minimum(dest, out_flat.shape[0] - 1)],
                        0.0)
    return jnp.zeros((T, d), out_flat.dtype).at[src_tok].add(
        contrib * w_sorted[:, None])


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
              capacity_factor: float | None = None, *,
              dispatch_groups: int = 0):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar fp32).

    ``dispatch_groups > 0`` splits tokens into G groups and dispatches each
    group independently (vmap). With G = the data-axis size, every group's
    sort/scatter is local to one data shard, so GSPMD keeps dispatch
    on-device and only reshards the (G, E, cap, d) expert buffer across the
    expert-parallel axis (all-to-all) instead of all-reducing a global
    scatter — the §Perf hillclimb for the MoE architectures. G=0 reproduces
    the single-group (paper-baseline) dispatch.
    """
    spec = cfg.moe
    if capacity_factor is None:
        capacity_factor = spec.capacity_factor
    if not dispatch_groups:
        dispatch_groups = spec.dispatch_groups
    # routing over the REAL experts; dispatch buffers sized to the padded
    # count so the expert dim shards cleanly (padded rows get no routes)
    E, k = spec.n_experts_padded, spec.top_k
    E_real = spec.n_experts
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                        # (T, E)
    top_w, top_i = jax.lax.top_k(probs, k)                         # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (over REAL experts).
    me = probs.mean(axis=0)                                        # (E_real,)
    one_hot = jax.nn.one_hot(top_i, E_real, dtype=jnp.float32)
    ce = one_hot.sum(axis=(0, 1)) / (T * k)                        # fraction
    aux = E_real * jnp.sum(me * ce) * spec.router_aux_coef

    G = dispatch_groups if (dispatch_groups and T % dispatch_groups == 0) \
        else 1
    Tg = T // G
    cap = max(1, int(capacity_factor * Tg * k / E))
    cap = -(-cap // 4) * 4                                         # pad to 4

    xg = xt.reshape(G, Tg, d)
    ig = top_i.reshape(G, Tg, k)
    wg = top_w.reshape(G, Tg, k)
    buf, dest, keep, src_tok, w_sorted = jax.vmap(
        lambda a, b, c: _dispatch_one_group(a, b, c, E, k, cap))(xg, ig, wg)
    # buf: (G, E, cap, d) — G rides the data axis, E the expert axis
    if G > 1:
        buf = _constrain(buf, ("data", "model", None, None))

    g_ = jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["wi_up"])
    # silu stays in bf16: the fp32 round-trip made GSPMD all-reduce fp32
    # activation grads in the backward (2x bytes) — §Perf iteration A3
    h = jax.nn.silu(g_) * u
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])                 # (G,E,cap,d)
    if G > 1:
        # bring every expert's outputs back to the token's data shard
        out = _constrain(out, ("data", None, None, None))

    y = jax.vmap(lambda o, de, ke, st, w: _combine_one_group(
        o.reshape(E * cap, d), de, ke, st, w, Tg, d))(
        out, dest, keep, src_tok, w_sorted)
    y = y.reshape(B, S, d)
    if G > 1:
        # keep the result (and its cotangent) sharded like the activations
        y = _constrain(y, ("data", None, None))
    return y, aux


# --------------------------------------------------- shard_map EP (A4 path)
def moe_apply_shard_map(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                        mesh, capacity_factor: float | None = None):
    """Expert-parallel MoE with EXPLICIT all-to-alls (§Perf iteration A4).

    shard_map over ("data","model"): each data shard dispatches its own
    tokens locally (same semantics as grouped dispatch with G = data size),
    then ONE all-to-all ships each model peer the slots of its local
    experts, expert FFNs run fully local, and the reverse all-to-all brings
    outputs home for a local combine. GSPMD's inferred all-gathers/
    all-reduces on the return path are replaced by the minimal token
    movement top-k routing actually requires.
    """
    from jax.sharding import PartitionSpec as P

    spec = cfg.moe
    cf = capacity_factor or spec.capacity_factor
    E, k = spec.n_experts_padded, spec.top_k
    E_real = spec.n_experts
    B, S, d = x.shape
    M = mesh.shape["model"]
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    D = 1
    for a in dp:
        D *= mesh.shape[a]
    assert E % M == 0, "padded experts must divide the model axis"
    Em = E // M
    T_loc = (B // D) * S
    assert T_loc % M == 0, "row tokens must divide the model axis"
    cap = max(4, -(-int(cf * (T_loc // M) * k / E) // 4) * 4)

    def block(xb, router, wi_g, wi_u, wo):
        # xb (B/D, S, d) is replicated across the model axis within a data
        # row — each model peer handles ITS 1/M slice of the row's tokens
        # (otherwise all M peers would duplicate the dispatch 16x).
        Tl = xb.shape[0] * xb.shape[1]
        Tm = Tl // M
        m_idx = jax.lax.axis_index("model")
        xt = jax.lax.dynamic_slice_in_dim(xb.reshape(Tl, d), m_idx * Tm, Tm)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        me = jax.lax.pmean(probs.mean(axis=0), (*dp, "model"))
        one_hot = jax.nn.one_hot(top_i, E_real, dtype=jnp.float32)
        ce = jax.lax.pmean(one_hot.sum(axis=(0, 1)) / (Tm * k),
                           (*dp, "model"))
        aux = E_real * jnp.sum(me * ce) * spec.router_aux_coef

        buf, dest, keep, src_tok, w_sorted = _dispatch_one_group(
            xt, top_i, top_w, E, k, cap)              # (E, cap, d)
        # ship each model peer its Em experts' slots (self-inverse a2a:
        # split==concat axis keeps the VJP layout trivial)
        buf = buf.reshape(M, Em, cap, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        recv = recv.transpose(1, 0, 2, 3).reshape(Em, M * cap, d)
        g_ = jnp.einsum("ecd,edf->ecf", recv, wi_g)
        u = jnp.einsum("ecd,edf->ecf", recv, wi_u)
        h = jax.nn.silu(g_) * u
        out = jnp.einsum("ecf,efd->ecd", h, wo)        # (Em, M*cap, d)
        # reverse all-to-all: outputs go home to their source data shard
        out = out.reshape(Em, M, cap, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0)
        out_flat = out.reshape(E * cap, d)
        y_m = _combine_one_group(out_flat, dest, keep, src_tok, w_sorted,
                                 Tm, d)               # (Tm, d)
        # reassemble the row's tokens (activations are model-replicated
        # outside the MoE block)
        y = jax.lax.all_gather(y_m, "model", tiled=True)   # (Tl, d)
        return y.reshape(xb.shape), aux

    from repro.compat import shard_map

    dp_spec = dp if len(dp) > 1 else dp[0]
    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
