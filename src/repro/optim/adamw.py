"""In-house AdamW (+ cosine schedule, global-norm clipping).

optax is not available offline; this implements the standard decoupled
weight-decay Adam on arbitrary pytrees with fp32 moments (params may be
bf16 — the canonical mixed-precision setup).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def clip_by_global_norm(grads: Any, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
