"""Production mesh construction.

Target: TPU v5e pods — 16x16 = 256 chips per pod; multi-pod = 2 pods = 512.
Functions (not module-level constants) so importing never touches jax
device state; the dry-run sets XLA_FLAGS before calling these.
"""
from __future__ import annotations

import jax

POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))
