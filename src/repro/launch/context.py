"""Mesh context: lets model-layer code (e.g. the shard_map MoE path) reach
the concrete mesh the launcher is driving, without threading it through
every function signature."""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from jax.sharding import Mesh

_CURRENT: list[Optional[Mesh]] = [None]


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    _CURRENT[0] = mesh


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[0]


@contextmanager
def mesh_context(mesh: Mesh):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        yield mesh
    finally:
        _CURRENT[0] = prev
