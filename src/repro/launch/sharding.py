"""Sharding rules: params / batches / caches -> NamedSharding trees.

Policy (DESIGN.md §5):
  * activations & batches: batch dim -> data axes ("pod","data") when they
    divide it, else replicated;
  * params: last dim -> "model" (tensor parallel), second-to-last -> data
    axes (FSDP/ZeRO-3) — each only when divisible, else replicated;
  * MoE expert tensors (..., E, d, f): E -> "model" when divisible
    (expert parallelism; qwen3's 128 experts), else the generic rule
    (granite's 40 experts shard d_ff instead);
  * decode KV caches (L, B, W, K, hd): B -> data, W -> "model"
    (flash-decoding-style sequence sharding); B=1 long-context shards W
    across every axis;
  * recurrent states: B -> data, heads/channels -> "model".

Divisibility-gated helpers make every rule total: any dim that doesn't
divide its axis is simply replicated (handles kv=8 heads on a 16-wide model
axis, vocab 49155, 20-head whisper...).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _fit(mesh: Mesh, dim: int, axis) -> Optional[Any]:
    """axis if it divides dim; for tuple axes, tries progressively shorter
    prefixes (('pod','data') -> 'data'); else None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        for cut in range(len(axis), 0, -1):
            sub = axis[:cut] if cut > 1 else axis[cut - 1]
            if dim % _axis_size(mesh, sub) == 0:
                return sub
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _path_has(path, *names) -> bool:
    keys = {getattr(k, "key", getattr(k, "name", "")) for k in path}
    return any(n in keys for n in names)


# ------------------------------------------------------------------ params
def param_pspec(path, shape: tuple[int, ...], mesh: Mesh) -> P:
    nd = len(shape)
    spec: list = [None] * nd
    da = data_axes(mesh)
    if nd == 0 or nd == 1:
        return P(*spec)
    if _path_has(path, "router"):
        # routers are tiny; replicating them avoids a partial-sum all-reduce
        # of (T, d) activation grads every layer (§Perf iteration A2)
        return P(*spec)
    moe_leaf = _path_has(path, "moe") and nd >= 3
    if moe_leaf:
        # (..., E, d, f) — EXPERT-PARALLEL ONLY: E -> model, replicated over
        # data. FSDP-sharding d/f caused contraction partial-sums that
        # GSPMD turned into TB-scale all-reduces (§Perf iteration A2); the
        # replicated expert shards are only a few GB.
        e_dim = nd - 3
        if _fit(mesh, shape[e_dim], "model"):
            spec[e_dim] = "model"
            return P(*spec)
    # generic: last -> model, second-to-last -> fsdp/data
    spec[nd - 1] = _fit(mesh, shape[nd - 1], "model")
    spec[nd - 2] = _fit(mesh, shape[nd - 2], da)
    return P(*spec)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf.shape, mesh)),
        params_shape)


def opt_state_shardings(opt_shape: Any, mesh: Mesh) -> Any:
    """Adam moments mirror the param layout; step counter replicated."""
    def rule(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_pspec(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(rule, opt_shape)


# ----------------------------------------------------------------- batches
def batch_pspec(path, shape: tuple[int, ...], mesh: Mesh) -> P:
    nd = len(shape)
    spec: list = [None] * nd
    da = data_axes(mesh)
    if nd == 0:
        return P()
    if _path_has(path, "cache"):
        return cache_pspec(path, shape, mesh)
    spec[0] = _fit(mesh, shape[0], da)           # batch dim
    return P(*spec)


def cache_pspec(path, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Decode caches. KV (L,B,W,K,hd): B->data, W->model (seq-sharded);
    states (..., B, H/P/..., ...): B->data, widest trailing dim -> model."""
    name = getattr(path[-1], "key", "") if path else ""
    nd = len(shape)
    spec: list = [None] * nd
    da = data_axes(mesh)
    if nd == 0 or nd == 1:
        return P(*spec)
    if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
        L, B, W, K, hd = shape
        spec[1] = _fit(mesh, B, da)
        if spec[1] is None and B == 1:
            # long-context single sequence: shard the window everywhere
            spec[2] = _fit(mesh, W, (*((da,) if isinstance(da, str) else da),
                                     "model"))
            if spec[2] is None:
                spec[2] = _fit(mesh, W, "model")
        else:
            spec[2] = _fit(mesh, W, "model")
        return P(*spec)
    # recurrent / conv states: batch dim sits after the layer-stack dims —
    # grouped zamba2 states are (G, per, B, ...); everything else (L, B, ...)
    if _path_has(path, "trailing_ssm"):
        b_idx = 1
    elif _path_has(path, "ssm"):
        b_idx = 2
    else:
        b_idx = 1
    b_idx = min(b_idx, nd - 1)
    spec[b_idx] = _fit(mesh, shape[b_idx], da)
    # shard one wide trailing dim on model
    for i in range(b_idx + 1, nd):
        if _fit(mesh, shape[i], "model") and shape[i] >= 16:
            spec[i] = "model"
            break
    return P(*spec)


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, batch_pspec(path, leaf.shape, mesh)),
        batch_shape)
