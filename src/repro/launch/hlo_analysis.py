"""Trip-count-aware static analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count, which makes it useless for scan-over-layers models (an 88-layer
stack reports 1/88th of its FLOPs). This analyzer walks the computation
graph, multiplies while bodies by their ``known_trip_count`` backend config,
and produces:

  flops        — dot FLOPs (2·M·N·K), trip-count aware
  traffic      — approximate HBM bytes: operand+result bytes of schedulable
                 (non-fused) ops; fusion internals are VMEM/register traffic
                 and excluded — this matches the TPU memory hierarchy
  collectives  — per-kind operand bytes of every collective, trip-aware
  top_dots / top_collectives — largest contributors with op metadata
                 (the §Perf hillclimbing reads these)

All quantities are PER DEVICE: the input is the per-partition module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

from repro.compat import hlo_operand_name

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+|[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w\.\-]+|[\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w\.\-]+|[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+|[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id"}


def _parse_types(text: str):
    """All array types in a type expression -> list of (dtype, dims)."""
    return [(m.group(1), m.group(2)) for m in _TYPE_RE.finditer(text)]


def _type_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_types(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims(text: str) -> list[int]:
    m = _TYPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: dict = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.collectives.items():
            self.collectives[k] += v * mult


def _split_rhs(rhs: str):
    """rhs after '=': returns (result_type_str, opcode, args_str, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        rtype, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.index(" ")
        rtype, rest = rhs[:sp], rhs[sp + 1:].strip()
    m = re.match(r"([\w\-\.]+)\(", rest)
    if not m:
        return rtype, "", "", rest
    opcode = m.group(1)
    depth, start = 0, m.end() - 1
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    args = rest[start + 1:i]
    attrs = rest[i + 1:]
    return rtype, opcode, args, attrs


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Op]] = {}
        self.shapes: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo_sched: dict[str, Cost] = {}
        self._memo_fused: dict[str, Cost] = {}
        self.top_dots: list = []
        self.top_collectives: list = []
        self._dot_sites: dict[str, tuple[float, str]] = {}
        self._coll_sites: dict[str, tuple[float, str]] = {}

    # -------------------------------------------------------------- parse
    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            ms = _COMP_START.match(line)
            if ms:
                cur = ms.group(2).lstrip("%")
                self.comps[cur] = []
                self.shapes[cur] = {}
                if ms.group(1):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mo = _OP_RE.match(line)
            if not mo:
                continue
            name = mo.group(2).lstrip("%")
            rtype, opcode, args, attrs = _split_rhs(mo.group(3))
            # newer XLA prints typed operands ("f32[64,128]{1,0} %x");
            # normalize to the bare name the shape table is keyed by
            operands = [hlo_operand_name(a) for a in _split_args(args)]
            op = Op(name, opcode, rtype, operands, attrs, line)
            self.comps[cur].append(op)
            self.shapes[cur][name] = rtype

    # ------------------------------------------------------------- costing
    def _dot_flops(self, comp: str, op: Op) -> float:
        res = _dims(op.result_type)
        lhs_name = op.operands[0] if op.operands else ""
        lhs_type = self.shapes[comp].get(lhs_name, "")
        lhs = _dims(lhs_type)
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        k = 1
        if mc and lhs:
            for d in mc.group(1).split(","):
                if d:
                    k *= lhs[int(d)]
        n = 1
        for d in res:
            n *= d
        return 2.0 * n * k

    def comp_cost(self, name: str, fused: bool) -> Cost:
        memo = self._memo_fused if fused else self._memo_sched
        if name in memo:
            return memo[name]
        cost = Cost()
        memo[name] = cost  # guard against cycles
        for op in self.comps.get(name, ()):
            if op.opcode == "dot":
                fl = self._dot_flops(name, op)
                cost.flops += fl
                meta = _meta(op.line)
                prev = self._dot_sites.get(meta, (0.0, meta))
                self._dot_sites[meta] = (prev[0] + fl, meta)
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                by = sum(_type_bytes(self.shapes[name].get(o, ""))
                         for o in op.operands)
                if by == 0:
                    by = _type_bytes(op.result_type)
                cost.collectives[base] += by
                meta = _meta(op.line)
                prev = self._coll_sites.get((base, meta), (0.0, meta))
                self._coll_sites[(base, meta)] = (prev[0] + by, meta)
            if op.opcode == "while":
                trip = 1
                mt = _TRIP_RE.search(op.attrs)
                if mt:
                    trip = int(mt.group(1))
                body = _CALLS_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                if body:
                    cost.add(self.comp_cost(body.group(1).lstrip("%"), fused),
                             trip)
                if cond:
                    cost.add(self.comp_cost(cond.group(1).lstrip("%"), fused),
                             trip)
            elif op.opcode == "conditional":
                mb = _BRANCHES_RE.search(op.attrs)
                if mb:
                    branches = [b.strip().lstrip("%")
                                for b in mb.group(1).split(",")]
                    costs = [self.comp_cost(b, fused) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.traffic)
                        cost.add(best)
            elif op.opcode in ("fusion",):
                mcall = _CALLS_RE.search(op.attrs)
                if mcall:
                    cost.add(self.comp_cost(mcall.group(1).lstrip("%"), True))
            elif op.opcode in ("call", "async-start", "custom-call"):
                mcall = _CALLS_RE.search(op.attrs)
                if mcall:
                    cost.add(self.comp_cost(mcall.group(1).lstrip("%"), fused))
            # traffic: schedulable ops move operands+results through HBM
            if not fused and op.opcode not in _NO_TRAFFIC:
                by = _type_bytes(op.result_type)
                for o in op.operands:
                    by += _type_bytes(self.shapes[name].get(o, ""))
                cost.traffic += by
        memo[name] = cost
        return cost

    def analyze(self) -> dict:
        assert self.entry, "no ENTRY computation found"
        cost = self.comp_cost(self.entry, False)
        self.top_dots = sorted(self._dot_sites.values(), reverse=True)[:12]
        self.top_collectives = sorted(
            ((v, k[0], k[1]) for k, (v, _) in self._coll_sites.items()),
            reverse=True)[:12]
        coll = dict(cost.collectives)
        coll["total"] = sum(cost.collectives.values())
        return {
            "flops": cost.flops,
            "traffic": cost.traffic,
            "collectives": coll,
            "top_dots": [(f, m) for f, m in self.top_dots],
            "top_collectives": self.top_collectives,
        }


def _split_args(args: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in args:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [a for a in (s.strip() for s in out) if a]


def _meta(line: str) -> str:
    m = re.search(r'op_name="([^"]*)"', line)
    return m.group(1) if m else "?"


def analyze_hlo(hlo_text: str) -> dict:
    return HloAnalysis(hlo_text).analyze()
