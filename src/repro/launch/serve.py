"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Boots the real-execution EPD engine (E/P/D threads, IRP, ψ_EP/ψ_PD
migrations) on a reduced model and drives a Poisson request stream through
it, reporting TTFT/TPOT/SLO attainment — the paper's online experiment shape
running on live tensors.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig, ServeRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pixtral-12b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--mm-items", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--irp-workers", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=args.irp_workers,
        max_new_tokens=args.max_new_tokens))
    engine.start()
    print(f"[serve] arch={cfg.name} (reduced) irp={args.irp_workers} "
          f"rate={args.rate}/s requests={args.requests}")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        mm = None
        pos = None
        if cfg.modality is not None and cfg.family != "audio":
            mm = rng.standard_normal(
                (args.mm_items, cfg.modality.enc_d_model)).astype(np.float32) * 0.1
            pos = np.arange(1, args.mm_items + 1, dtype=np.int32)
        r = ServeRequest(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            mm_embeds=mm, mm_positions=pos,
            max_new_tokens=args.max_new_tokens)
        engine.submit(r)
        reqs.append(r)
        time.sleep(rng.exponential(1.0 / args.rate))

    ttfts, tpots = [], []
    for r in reqs:
        out = engine.result(r.req_id, timeout=600)
        ttfts.append(out.ttft)
        tpots.append(out.tpot)
        print(f"[serve] req {out.req_id}: ttft={out.ttft*1e3:8.1f}ms "
              f"tpot={out.tpot*1e3:6.1f}ms tokens={out.tokens[:6]}...")
    engine.stop()
    print(f"[serve] mean ttft={np.mean(ttfts)*1e3:.1f}ms "
          f"mean tpot={np.mean(tpots)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
