import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) this lowers + compiles the exact
production step function — train_step / prefill_step / serve_step — against
ShapeDtypeStruct stand-ins (zero device allocation) on the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh, prints memory_analysis / cost_analysis,
and extracts the roofline terms (compute / memory / collective) from the
compiled artifact. Results append to a JSONL consumed by EXPERIMENTS.md and
``benchmarks/roofline.py``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
      --shape train_4k [--multi-pod] [--out runs/dryrun.jsonl]
"""
import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, opt_state_shardings,
                                   param_shardings)
from repro.models import build_model, input_specs, uses_sliding_window_variant
from repro.optim import AdamWConfig, adamw_init, adamw_update

# TPU v5e constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
ICI_LINKS = 4                # v5e 2D torus: 4 links/chip

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_DEF_RE = re.compile(r"^\s*(%[\w\.\-]+|[\w\.\-]+) = ([\w\(\)]*)")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (partitioned) HLO."""
    sizes: dict[str, int] = {}
    per_op: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(%?[\w\.\-]+) = ", line)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        rest = line[m.end():]
        tm = _TYPE_RE.match(rest.lstrip("(").strip())
        if tm:
            sizes[name] = _shape_bytes(tm.group(1), tm.group(2))
        opm = re.search(r"\)?\s([a-z\-]+)\(", rest)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\s{c}(-start)?\(", rest):
                op = c
                break
        if op is None:
            continue
        # operand names inside the call parens
        args = re.search(rf"{op}(?:-start)?\((.*?)\)", rest)
        total = 0
        if args:
            for token in args.group(1).split(","):
                token = token.strip().lstrip("%")
                total += sizes.get(token, 0)
        if total == 0:
            # fall back to result size
            tm2 = _TYPE_RE.search(rest)
            if tm2:
                total = _shape_bytes(tm2.group(1), tm2.group(2))
        per_op[op] += total
    per_op["total"] = sum(per_op.values())
    return per_op


# ------------------------------------------------------------- step builder
def make_step(cfg: ArchConfig, shape: InputShape, *,
              block_causal_skip: bool = False):
    """Returns (fn, arg_specs) for the step the shape exercises."""
    model = build_model(cfg, block_causal_skip=block_causal_skip)
    batch_spec = input_specs(cfg, shape)
    params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    if shape.mode == "train":
        acfg = AdamWConfig()
        opt_spec = jax.eval_shape(lambda: adamw_init(params_spec))

        def train_step(params, opt_state, batch):
            def lf(p):
                loss, metrics = model.loss_fn(p, batch=batch)
                return loss
            loss, grads = jax.value_and_grad(lf)(params)
            params, opt_state, om = adamw_update(params, grads, opt_state, acfg)
            return params, opt_state, loss

        return train_step, (params_spec, opt_spec, batch_spec)

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch=batch)
        return prefill_step, (params_spec, batch_spec)

    def serve_step(params, batch):
        return model.decode_step(params, batch=batch)
    return serve_step, (params_spec, batch_spec)


def arg_shardings(arg_specs, mesh):
    out = []
    for spec in arg_specs:
        leaves = jax.tree.leaves(spec)
        if leaves and any(
                getattr(p[-1], "key", None) in ("mu", "nu", "step")
                for p, _ in jax.tree_util.tree_flatten_with_path(spec)[0][:1]):
            out.append(opt_state_shardings(spec, mesh))
        else:
            out.append(None)
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            block_causal_skip: bool = False, moe_groups: int = 0,
            pad_experts: int = 0, moe_a2a: bool = False,
            tag: str = "baseline",
            out_path: str | None = None, print_hlo_to: str | None = None):
    cfg = get_config(arch)
    if (moe_groups or pad_experts or moe_a2a) and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe,
                dispatch_groups=moe_groups or cfg.moe.dispatch_groups,
                pad_experts=pad_experts or cfg.moe.pad_experts,
                use_shard_map=moe_a2a or cfg.moe.use_shard_map))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    fn, arg_specs = make_step(cfg, shape,
                              block_causal_skip=block_causal_skip)

    shardings = []
    for i, spec in enumerate(arg_specs):
        if shape.mode == "train" and i == 0:
            shardings.append(param_shardings(spec, mesh))
        elif shape.mode == "train" and i == 1:
            shardings.append(opt_state_shardings(spec, mesh))
        elif i == 0 and shape.mode != "train":
            shardings.append(param_shardings(spec, mesh))
        else:
            shardings.append(batch_shardings(spec, mesh))

    from repro.launch.context import mesh_context
    t0 = time.time()
    with mesh, mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=tuple(shardings))
        lowered = jitted.lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        from repro.compat import cost_analysis
        cost = cost_analysis(compiled)
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        hlo = compiled.as_text()

    # trip-count-aware static analysis (XLA's cost_analysis counts while
    # bodies once — see hlo_analysis.py); XLA numbers kept for reference.
    ana = analyze_hlo(hlo)
    coll = ana["collectives"]
    flops = float(ana["flops"])

    # HBM-traffic proxy: compiled buffer sizes (args read + outputs written
    # + temps written&read). Per-op sums over CPU-optimized HLO grossly
    # overcount for the TPU target (CPU barely fuses), so the analyzer's
    # per-op figure is kept only as an upper bound.
    mem_fields_early = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                mem_fields_early[f] = int(getattr(mem, f))
            except Exception:
                pass
    if {"argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes"} <= mem_fields_early.keys():
        byts = float(mem_fields_early["argument_size_in_bytes"]
                     + mem_fields_early["output_size_in_bytes"]
                     + 2 * mem_fields_early["temp_size_in_bytes"])
    else:
        byts = float(ana["traffic"])

    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll["total"] / (ICI_LINKS * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N_active·D per trained token; decode/prefill use 2·N·D
    D_tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    model_flops = mult * cfg.active_param_count() * D_tokens / n_chips
    useful = model_flops / flops if flops else 0.0

    mem_fields = mem_fields_early

    rec = {
        "arch": arch, "shape": shape_name, "mode": shape.mode,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": int(n_chips),
        "tag": tag,
        "sw_variant": uses_sliding_window_variant(cfg, shape),
        "block_causal_skip": block_causal_skip,
        "flops_per_device": flops, "bytes_per_device": byts,
        "traffic_upper_bound": float(ana["traffic"]),
        "collective_bytes": coll, "memory_analysis": mem_fields,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if k in ("flops", "bytes accessed")},
        "roofline_s": terms, "dominant": dominant,
        "model_flops_per_device": model_flops, "useful_flop_ratio": useful,
        "top_dots": ana["top_dots"][:6],
        "top_collectives": ana["top_collectives"][:6],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    print(json.dumps(rec))
    print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']} OK | "
          f"compute={t_compute*1e3:.2f}ms memory={t_memory*1e3:.2f}ms "
          f"collective={t_coll*1e3:.2f}ms dominant={dominant} "
          f"useful={useful:.2f}", file=sys.stderr)
    if mem is not None:
        print(f"[dryrun] memory_analysis: {mem_fields}", file=sys.stderr)
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    if print_hlo_to:
        with open(print_hlo_to, "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED + ["all"])
    ap.add_argument("--shape", required=True,
                    choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--block-causal-skip", action="store_true",
                    help="beyond-paper causal-block skip optimization")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="beyond-paper grouped MoE dispatch (per-data-shard)")
    ap.add_argument("--pad-experts", type=int, default=0,
                    help="pad expert count for clean expert-parallel sharding")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="shard_map expert-parallel MoE with explicit "
                         "all-to-alls (§Perf A4)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    for a in archs:
        for s in shapes:
            run_one(a, s, multi_pod=args.multi_pod,
                    block_causal_skip=args.block_causal_skip,
                    moe_groups=args.moe_groups,
                    pad_experts=args.pad_experts,
                    moe_a2a=args.moe_a2a,
                    tag=args.tag, out_path=args.out,
                    print_hlo_to=args.dump_hlo)


if __name__ == "__main__":
    main()
