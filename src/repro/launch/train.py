"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real steps on the local devices (reduced configs on CPU; full configs
on a TPU slice — same code path, the mesh just grows). Wires the data
pipeline, AdamW, checkpointing, and per-step metrics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, list_archs
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    acfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.2f}M "
          f"devices={len(jax.devices())}")

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"[train] restored step {start} from {args.ckpt_dir}")

    @jax.jit
    def train_step(params, opt_state, batch):
        def lf(p):
            loss, metrics = model.loss_fn(p, batch=batch)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, acfg)
        return params, opt_state, loss, {**metrics, **om}

    pipe = TokenPipeline(cfg, args.batch, args.seq_len)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = pipe.batch_at(step)
        params, opt_state, loss, metrics = train_step(params, opt_state, batch)
        if step % args.log_every == 0:
            loss_f = float(loss)
            assert loss_f == loss_f, f"NaN loss at step {step}"
            print(f"[train] step={step:5d} loss={loss_f:8.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, (params, opt_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, (params, opt_state))
    print(f"[train] done: final loss {float(loss):.4f}")
    return float(loss)


if __name__ == "__main__":
    main()
