"""The paper's own evaluation LMMs (Appendix E.2).

These drive the EPD reproduction benchmarks (SLO attainment, TTFT, memory
tables). Backbone dims follow the public model cards:

- MiniCPM-V 2.6 = SigLip-400M encoder + Qwen2-7B LLM  [arXiv:2408.01800]
- InternVL2-8B  = InternViT-300M-448px + internlm2_5-7b-chat [CVPR'24]
- InternVL2-26B = InternViT-6B-448px-V1-5 + internlm2-chat-20b
- ultravox-v0_3 = whisper-style audio encoder + LLaMA3.1-8B (Appendix A.1)

``tokens_per_item`` encodes the paper's observation that MiniCPM produces far
fewer image tokens per patch (64) than InternVL (256) — this asymmetry drives
the prefill-heaviness differences in Figure 5.
"""
from repro.configs.base import ArchConfig, ModalitySpec, register

MINICPM_V_2_6 = register(ArchConfig(
    name="minicpm-v-2.6",
    family="vlm",
    n_layers=28,                 # Qwen2-7B backbone
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=151646,
    max_context=32_768,
    modality=ModalitySpec(
        kind="vision",
        d_frontend=1152,         # SigLip-400M
        enc_layers=27,
        enc_d_model=1152,
        enc_heads=16,
        enc_d_ff=4304,
        tokens_per_item=64,      # MiniCPM's compressed image tokens per slice
        enc_tokens_per_item=1024,  # (448/14)^2 SigLip tokens pre-resampler
        preprocess_s=0.02,
        patches_at_res={(313, 234): 1, (787, 444): 3, (4032, 3024): 10},
    ),
    source="arXiv:2408.01800",
))

INTERNVL2_8B = register(ArchConfig(
    name="internvl2-8b",
    family="vlm",
    n_layers=32,                 # internlm2_5-7b-chat backbone
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=92544,
    max_context=8_192,
    modality=ModalitySpec(
        kind="vision",
        d_frontend=1024,         # InternViT-300M-448px
        enc_layers=24,
        enc_d_model=1024,
        enc_heads=16,
        enc_d_ff=4096,
        tokens_per_item=256,
        enc_tokens_per_item=1024,
        preprocess_s=0.02,
        tile_budget=12,
        patches_at_res={(313, 234): 1, (787, 444): 3, (4032, 3024): 13},
    ),
    source="hf:OpenGVLab/InternVL2-8B",
))

INTERNVL2_26B = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,                 # internlm2-chat-20b backbone
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    max_context=8_192,
    modality=ModalitySpec(
        kind="vision",
        d_frontend=3200,         # InternViT-6B-448px-V1-5
        enc_layers=45,
        enc_d_model=3200,
        enc_heads=25,
        enc_d_ff=12800,
        tokens_per_item=256,
        enc_tokens_per_item=1024,
        preprocess_s=0.02,
        tile_budget=12,
        patches_at_res={(313, 234): 1, (787, 444): 3, (4032, 3024): 13},
    ),
    source="hf:OpenGVLab/InternVL2-26B",
))

ULTRAVOX_V0_3 = register(ArchConfig(
    name="ultravox-v0_3",
    family="vlm",                # audio-frontend LLM (decoder-only, not encdec)
    n_layers=32,                 # LLaMA3.1-8B backbone
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    modality=ModalitySpec(
        kind="audio",
        d_frontend=1280,         # whisper-medium-style encoder
        enc_layers=24,
        enc_d_model=1024,
        enc_heads=16,
        enc_d_ff=4096,
        tokens_per_item=188,     # ~6s audio clip -> tokens after stacking
        enc_tokens_per_item=750,
        preprocess_s=0.01,
        patches_at_res={(313, 234): 1, (787, 444): 1, (4032, 3024): 1},
    ),
    source="hf:fixie-ai/ultravox-v0_3",
))
