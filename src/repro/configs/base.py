"""Architecture config system.

Every assigned architecture (and the paper's own LMMs) is an ``ArchConfig``
registered under its public id, selectable via ``--arch <id>`` in the
launchers. Full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation); ``reduced()`` yields the CPU-smoke variant
(<=2 layers, d_model<=512, <=4 experts) used by tests and examples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts spec. ``d_ff`` in the parent is per-expert."""
    n_experts: int
    top_k: int
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # >0: dispatch per data-parallel group (beyond-paper §Perf optimization:
    # keeps the sort/scatter local to a data shard; see models/moe.py)
    dispatch_groups: int = 0
    # >0: pad expert weights to this count so the expert dim divides the
    # model axis (e.g. granite's 40 -> 48 on a 16-wide mesh). Padded experts
    # receive no routes; only their weight memory is spent.
    pad_experts: int = 0
    # shard_map expert-parallel path with explicit all-to-alls on the
    # dispatch/return (beyond-paper §Perf iteration A4). Requires a mesh in
    # repro.launch.context and batch % data-axis == 0; falls back otherwise.
    use_shard_map: bool = False

    @property
    def n_experts_padded(self) -> int:
        return max(self.n_experts, self.pad_experts)


@dataclass(frozen=True)
class SSMSpec:
    """Mamba2-style SSD spec."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def n_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVSpec:
    """RWKV6 (Finch) spec: data-dependent decay linear attention."""
    head_dim: int = 64

    def n_heads(self, d_model: int) -> int:
        return d_model // self.head_dim


@dataclass(frozen=True)
class ModalitySpec:
    """Modality frontend description.

    The frontend itself (mel+conv codec / ViT patchifier) is STUBBED:
    ``input_specs`` hands the backbone precomputed embeddings of shape
    ``(B, n_items * tokens_per_item, d_frontend)``. The projector
    (d_frontend -> d_model) and everything downstream is real. ``enc_layers``
    / ``enc_d_model`` describe the encoder transformer for the E-stage cost
    model (and, for whisper, the *real* encoder transformer).
    """
    kind: str                      # "vision" | "audio"
    d_frontend: int
    enc_layers: int
    enc_d_model: int
    enc_heads: int
    enc_d_ff: int
    tokens_per_item: int           # OUTPUT mm tokens per image-patch / clip
    # tokens the encoder itself processes per patch (pre-compression; e.g.
    # (448/14)^2 = 1024 ViT tokens vs 64 output tokens after MiniCPM's
    # resampler). Drives the E-stage compute cost.
    enc_tokens_per_item: int = 0   # 0 -> same as tokens_per_item
    preprocess_s: float = 0.0      # host preprocessing per patch (resize etc.)
    # InternVL-style dynamic tiling divides a fixed tile budget across the
    # images of a request (0 = unlimited, MiniCPM-style per-image slicing)
    tile_budget: int = 0
    # patches per image at the paper's three eval resolutions (W,H)
    patches_at_res: dict[tuple[int, int], int] = field(
        default_factory=lambda: {(313, 234): 1, (787, 444): 3, (4032, 3024): 10}
    )

    @property
    def enc_tokens(self) -> int:
        return self.enc_tokens_per_item or self.tokens_per_item


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # query heads; 0 => attention-free
    n_kv_heads: int
    d_ff: int                      # per-expert if moe is set
    vocab: int
    source: str = ""
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    rwkv: Optional[RWKVSpec] = None
    modality: Optional[ModalitySpec] = None
    attn_every: int = 0            # hybrid: a shared attn block every N layers
    n_enc_layers: int = 0          # enc-dec (whisper): encoder depth
    sliding_window: int = 0        # 0 = full attention
    long_context_window: int = 8192   # SW used for the long_500k dense variant
    max_context: int = 131_072     # OOCL limit (paper App. A.2)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------- derived
    @property
    def head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def has_encoder_stage(self) -> bool:
        """True if the arch has a multimodal E stage (EPD applies fully)."""
        return self.modality is not None

    def attn_layer_ids(self) -> list[int]:
        """For hybrid archs: indices of (shared) attention layers."""
        if self.attn_every <= 0:
            return [] if self.family in ("ssm",) else list(range(self.n_layers))
        return [i for i in range(self.n_layers) if (i + 1) % self.attn_every == 0]

    # ------------------------------------------------------- param counting
    def param_count(self) -> int:
        """Approximate parameter count (used by the cost/memory model)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        n = emb
        attn_ids = set(self.attn_layer_ids())
        hd = self.head_dim
        attn_p = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d if self.n_heads else 0
        if self.moe is not None:
            ffn_p = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
        else:
            ffn_p = 3 * d * f
        if self.family == "hybrid":
            ssm_p = self._ssm_params()
            n_attn = len(attn_ids)
            n_ssm = self.n_layers - n_attn
            # shared attention block: ONE set of weights reused
            n += n_ssm * ssm_p + (attn_p + ffn_p) + self.n_layers * 2 * d
        elif self.family == "ssm" and self.rwkv is not None:
            n += self.n_layers * (self._rwkv_params() + ffn_p + 2 * d)
        elif self.family == "ssm":
            n += self.n_layers * (self._ssm_params() + 2 * d)
        elif self.is_encdec:
            # decoder: self-attn + cross-attn + ffn; encoder: self-attn + ffn
            n += self.n_layers * (2 * attn_p + ffn_p + 3 * d)
            m = self.modality
            if m:
                ea = m.enc_d_model * m.enc_d_model * 4
                ef = 3 * m.enc_d_model * m.enc_d_ff
                n += m.enc_layers * (ea + ef)
        else:
            n += self.n_layers * (attn_p + ffn_p + 2 * d)
            if self.modality is not None:
                m = self.modality
                ea = m.enc_d_model * m.enc_d_model * 4
                ef = 3 * m.enc_d_model * m.enc_d_ff
                n += m.enc_layers * (ea + ef) + m.d_frontend * self.d_model
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full_ffn = self.moe.n_experts * 3 * d * f
        act_ffn = self.moe.top_k * 3 * d * f
        return int(self.param_count() - self.n_layers * (full_ffn - act_ffn))

    def encoder_param_count(self) -> int:
        """Params of the multimodal encoder only (E-stage memory model)."""
        m = self.modality
        if m is None:
            return 0
        ea = m.enc_d_model * m.enc_d_model * 4
        ef = 3 * m.enc_d_model * m.enc_d_ff
        return int(m.enc_layers * (ea + ef) + m.d_frontend * self.d_model)

    def _ssm_params(self) -> int:
        s = self.ssm or SSMSpec()
        d = self.d_model
        di = s.expand * d
        nh = s.n_heads(d)
        # in_proj -> (z, x, B, C, dt) with n_groups=1; conv over x,B,C; out_proj
        in_proj = d * (2 * di + 2 * s.d_state + nh)
        conv = (di + 2 * s.d_state) * s.d_conv
        out_proj = di * d
        return in_proj + conv + out_proj + 3 * nh + di

    def _rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,w projections + output + lora decays (approx)
        return 6 * d * d + 4 * d * 64

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per sequence token (across all caching layers)."""
        hd = self.head_dim
        n_attn = len(self.attn_layer_ids())
        kv = 2 * self.n_kv_heads * hd * dtype_bytes
        if self.is_encdec:
            return self.n_layers * kv  # decoder self-attn
        return n_attn * kv

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = 256
        heads = 0 if self.attention_free else 4
        kv = 0 if self.attention_free else max(1, min(self.n_kv_heads, 2))
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            d_ff=128 if self.moe else 512,
            vocab=512,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.moe is not None:
            # generous capacity so reduced-model smoke tests are drop-free
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2,
                                capacity_factor=4.0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=64, chunk=32)
        if self.rwkv is not None:
            kw["rwkv"] = replace(self.rwkv, head_dim=64)
        if self.modality is not None:
            kw["modality"] = replace(
                self.modality, d_frontend=128, enc_layers=2, enc_d_model=128,
                enc_heads=4, enc_d_ff=256, tokens_per_item=16)
        return replace(self, **kw)


# ---------------------------------------------------------------- registry
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro import configs as _pkg  # ensure registration side-effects ran
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _pkg
    return sorted(_REGISTRY)


# ------------------------------------------------------------- input shapes
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
