"""Zamba2-7B — Mamba2 backbone with a SHARED attention block every 6th layer.

[arXiv:2411.15242] 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64. The attention+MLP block weights are shared across all its
occurrences (Zamba's signature trick), which ``param_count`` reflects.
"""
from repro.configs.base import ArchConfig, SSMSpec, register

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=6,
    rope_theta=1e4,
    source="arXiv:2411.15242",
))
