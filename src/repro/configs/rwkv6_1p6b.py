"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay linear attention.

[arXiv:2404.05892] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
"""
from repro.configs.base import ArchConfig, RWKVSpec, register

RWKV6_1P6B = register(ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=7168,
    vocab=65536,
    rwkv=RWKVSpec(head_dim=64),
    source="arXiv:2404.05892",
))
