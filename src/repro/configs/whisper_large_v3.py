"""Whisper-large-v3 — encoder-decoder audio model. [arXiv:2212.04356]

32L (enc) + 32L (dec), d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The mel-spectrogram + conv frontend is a STUB (``input_specs`` provides
precomputed frame embeddings); the transformer encoder IS implemented and is
the EPD E stage; the decoder runs P (prefill w/ cross-attn cache) and D.
"""
from repro.configs.base import ArchConfig, ModalitySpec, register

WHISPER_LARGE_V3 = register(ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    modality=ModalitySpec(
        kind="audio",
        d_frontend=1280,
        enc_layers=32,
        enc_d_model=1280,
        enc_heads=20,
        enc_d_ff=5120,
        tokens_per_item=1500,       # frames per 30s clip after conv stub
        patches_at_res={(313, 234): 1, (787, 444): 1, (4032, 3024): 1},
    ),
    source="arXiv:2212.04356",
))
