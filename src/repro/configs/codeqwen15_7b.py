"""CodeQwen1.5-7B — qwen1.5 arch (MHA: kv == q heads).

[hf:Qwen/CodeQwen1.5-7B] 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416.
"""
from repro.configs.base import ArchConfig, register

CODEQWEN15_7B = register(ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    source="hf:Qwen/CodeQwen1.5-7B",
))
