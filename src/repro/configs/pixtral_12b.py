"""Pixtral-12B — pixtral-ViT frontend (stub) + mistral-nemo style decoder.

[hf:mistralai/Pixtral-12B-2409] 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072. Vision: 1024-dim ViT, 16x16 patches; the ViT is a STUB —
``input_specs`` provides precomputed patch embeddings; the projector and the
decoder are real. EPD's E stage applies fully (IRP shards patches).
"""
from repro.configs.base import ArchConfig, ModalitySpec, register

PIXTRAL_12B = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    modality=ModalitySpec(
        kind="vision",
        d_frontend=1024,
        enc_layers=24,
        enc_d_model=1024,
        enc_heads=16,
        enc_d_ff=4096,
        tokens_per_item=256,        # tokens per image patch-group
        enc_tokens_per_item=1024,
        preprocess_s=0.02,
        patches_at_res={(313, 234): 1, (787, 444): 4, (4032, 3024): 16},
    ),
    rope_theta=1e9,
    source="hf:mistralai/Pixtral-12B-2409",
))
