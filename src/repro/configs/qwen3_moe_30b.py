"""Qwen3-30B-A3B — 128 experts, top-8 routing.

[hf:Qwen/Qwen3-30B-A3B] 48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert)
vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ArchConfig, MoESpec, register

QWEN3_MOE_30B = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    moe=MoESpec(n_experts=128, top_k=8),
    source="hf:Qwen/Qwen3-30B-A3B",
))
