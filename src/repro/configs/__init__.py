"""Architecture config registry.

Importing this package registers every config. ``ASSIGNED`` lists the 10
architectures assigned from the public pool; ``PAPER_LMMS`` the paper's own
evaluation models.
"""
from repro.configs.base import (
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    ModalitySpec,
    MoESpec,
    RWKVSpec,
    SSMSpec,
    get_config,
    list_archs,
    register,
)

# registration side effects
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    granite_moe_3b,
    internlm2_20b,
    minitron_4b,
    mistral_large_123b,
    paper_lmms,
    pixtral_12b,
    qwen3_moe_30b,
    rwkv6_1p6b,
    whisper_large_v3,
    zamba2_7b,
)

ASSIGNED = [
    "zamba2-7b",
    "rwkv6-1.6b",
    "pixtral-12b",
    "granite-moe-3b-a800m",
    "mistral-large-123b",
    "internlm2-20b",
    "codeqwen1.5-7b",
    "whisper-large-v3",
    "qwen3-moe-30b-a3b",
    "minitron-4b",
]

PAPER_LMMS = ["minicpm-v-2.6", "internvl2-8b", "internvl2-26b", "ultravox-v0_3"]

__all__ = [
    "ASSIGNED",
    "INPUT_SHAPES",
    "PAPER_LMMS",
    "ArchConfig",
    "InputShape",
    "ModalitySpec",
    "MoESpec",
    "RWKVSpec",
    "SSMSpec",
    "get_config",
    "list_archs",
    "register",
]
