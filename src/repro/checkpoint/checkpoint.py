"""Checkpointing: pytree <-> .npz with a msgpack-encoded treedef.

orbax/flax are not available offline; this stores every leaf as an npz
entry keyed by its flattened index plus a msgpack sidecar describing the
tree structure and dtypes (bf16 stored as uint16 views — npz has no bf16).
Atomic on rename; keeps the last ``keep`` steps.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _to_numpy(leaf) -> tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(leaf))
    if str(arr.dtype) == _BF16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, dtype: str):
    if dtype == _BF16:
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(arr)


def _paths(tree: Any) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays, dtypes = {}, []
    for i, leaf in enumerate(leaves):
        arr, dt = _to_numpy(leaf)
        arrays[f"leaf_{i}"] = arr
        dtypes.append(dt)
    meta = msgpack.packb({"step": step, "dtypes": dtypes,
                          "paths": _paths(tree),
                          "treedef": str(treedef)})
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".npz.tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(meta, np.uint8), **arrays)
    os.replace(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int) -> None:
    ckpts = sorted(f for f in os.listdir(ckpt_dir)
                   if re.fullmatch(r"ckpt_\d+\.npz", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any,
                       step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))
    meta = msgpack.unpackb(bytes(data["__meta__"]))
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(leaves) == len(meta["dtypes"]), \
        f"leaf count mismatch: {len(leaves)} vs {len(meta['dtypes'])}"
    out = [_from_numpy(data[f"leaf_{i}"], meta["dtypes"][i])
           for i in range(len(leaves))]
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"]
