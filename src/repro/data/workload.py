"""Multimodal serving workloads (paper §4 Datasets).

``poisson_requests`` reproduces the synthetic workload: requests arrive via
a Poisson process with rate lambda; configurable prompt length, images per
request, image resolution, and output length (paper defaults: 22-token
prompt, 10 output tokens, 4032x3024 images). ``nextqa_like`` and
``videomme_like`` mimic the real-trace statistics the paper reports
(NextQA: text 4-21 tokens avg 11.42, output 1-7 avg 2.75, 8 frames;
Video-MME: 64 frames, MiniCPM frame config).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.request import SLO, Request


@dataclass(frozen=True)
class WorkloadSpec:
    rate: float                          # requests/s (Poisson)
    n_requests: int = 100
    prompt_len: int = 22
    n_items: int = 2                     # images (or clips) per request
    resolution: tuple[int, int] = (4032, 3024)
    output_len: int = 10
    slo: Optional[SLO] = None
    seed: int = 0


def _patches(cfg: ArchConfig, resolution) -> int:
    m = cfg.modality
    if m is None:
        return 0
    return m.patches_at_res.get(tuple(resolution), 1)


def poisson_requests(cfg: ArchConfig, spec: WorkloadSpec) -> list[Request]:
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / max(spec.rate, 1e-9), spec.n_requests)
    arrivals = np.cumsum(gaps)
    m = cfg.modality
    tokens_pp = m.tokens_per_item if m else 0
    return [
        Request(req_id=i, arrival=float(arrivals[i]),
                prompt_len=spec.prompt_len,
                n_items=spec.n_items if m else 0,
                patches_per_item=_patches(cfg, spec.resolution),
                tokens_per_patch=tokens_pp,
                output_len=spec.output_len, slo=spec.slo)
        for i in range(spec.n_requests)
    ]


def nextqa_like(cfg: ArchConfig, rate: float, n: int = 100, *,
                slo: Optional[SLO] = None, seed: int = 0) -> list[Request]:
    """NextQA trace statistics: 8 uniformly sampled frames per video."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    m = cfg.modality
    return [
        Request(req_id=i, arrival=float(arrivals[i]),
                prompt_len=int(rng.integers(4, 22)),
                n_items=8, patches_per_item=1,
                tokens_per_patch=m.tokens_per_item if m else 0,
                output_len=int(rng.integers(1, 8)), slo=slo)
        for i in range(n)
    ]


def videomme_like(cfg: ArchConfig, rate: float, n: int = 100, *,
                  n_frames: int = 64, slo: Optional[SLO] = None,
                  seed: int = 0) -> list[Request]:
    """Video-MME trace: n_frames uniformly sampled frames, MC-QA outputs."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    m = cfg.modality
    return [
        Request(req_id=i, arrival=float(arrivals[i]),
                prompt_len=int(rng.integers(16, 64)),
                n_items=n_frames, patches_per_item=1,
                tokens_per_patch=m.tokens_per_item if m else 0,
                output_len=int(rng.integers(1, 4)), slo=slo)
        for i in range(n)
    ]
