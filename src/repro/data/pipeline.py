"""Training data pipeline: deterministic synthetic token streams.

A real deployment would read tokenized shards; offline, the pipeline
generates reproducible batches (seeded per step) shaped exactly like the
training input_specs, including multimodal embedding payloads for VLM/audio
archs. Supports host-side sharding for multi-process data parallelism.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import mm_token_budget


@dataclass
class TokenPipeline:
    cfg: ArchConfig
    batch: int
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    n_shards: int = 1

    def __post_init__(self):
        if self.batch % self.n_shards:
            raise ValueError("batch must divide host shards")
        self._local = self.batch // self.n_shards

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, step, self.shard_id))
        B, S = self._local, self.seq_len
        toks = rng.integers(0, self.cfg.vocab, (B, S + 1), dtype=np.int32)
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if self.cfg.family == "audio":
            out["enc_frames"] = jnp.asarray(
                rng.standard_normal((B, S, self.cfg.d_model), np.float32)
                * 0.1, dtype=jnp.bfloat16)
        elif self.cfg.modality is not None:
            M = mm_token_budget(self.cfg, S)
            out["mm_embeds"] = jnp.asarray(
                rng.standard_normal((B, M, self.cfg.modality.enc_d_model),
                                    np.float32) * 0.1, dtype=jnp.bfloat16)
            out["mm_positions"] = jnp.broadcast_to(
                jnp.arange(1, M + 1, dtype=jnp.int32)[None], (B, M))
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_token_batches(cfg: ArchConfig, batch: int, seq_len: int,
                            n_steps: int, seed: int = 0):
    pipe = TokenPipeline(cfg, batch, seq_len, seed)
    for step in range(n_steps):
        yield pipe.batch_at(step)
