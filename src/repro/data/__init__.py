from repro.data.workload import (WorkloadSpec, nextqa_like, poisson_requests,
                                 videomme_like)
from repro.data.pipeline import TokenPipeline, synthetic_token_batches

__all__ = ["WorkloadSpec", "nextqa_like", "poisson_requests", "videomme_like",
           "TokenPipeline", "synthetic_token_batches"]
