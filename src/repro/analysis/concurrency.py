"""Concurrency-discipline checker (RL001-RL004).

Extracts the lock/condvar acquisition graph from ``with lock:`` scopes
and ``.acquire()`` calls, including one level of light interprocedural
reasoning: a name-indexed call graph propagates "locks acquirable
during this call" and "this call can block", so
``with self._done_cv: req.mark_done(...)`` yields the
``engine.done_cv -> request.cv`` edge even though the inner acquisition
lives in another module.

The pass is deliberately name-based (no type inference): methods whose
names collide with builtin-container operations (``get``/``put``/
``pop``...) are excluded from propagation so ``self._entries.get(k)``
under a cache lock does not resolve to the cache's own ``get`` and
fabricate a self-deadlock.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

from repro.analysis import hierarchy
from repro.analysis.astutils import (ParentMap, attr_chain, call_name,
                                     enclosing_class_name, is_constant_true,
                                     iter_python_files, qualname_of, rel_path)
from repro.analysis.findings import Finding

# Method names too generic to resolve through the name-based call graph
# (builtin containers / strings / files share them).
RESOLUTION_DENYLIST = {
    "get", "put", "pop", "append", "appendleft", "popleft", "add",
    "remove", "discard", "clear", "update", "keys", "values", "items",
    "setdefault", "extend", "insert", "index", "count", "copy", "join",
    "split", "strip", "encode", "decode", "read", "write", "close",
    "open", "sort", "reverse", "format", "items", "wait", "notify",
    "notify_all", "acquire", "release", "set", "is_set",
}

_LOCKY_TAILS = ("lock", "mutex")
_CV_TAILS = ("_cv", "cond", "condition")


def _is_lock_chain(chain: tuple[str, ...]) -> bool:
    tail = chain[-1].lower()
    if any(t in tail for t in _LOCKY_TAILS):
        return True
    return tail == "cv" or any(tail.endswith(t) for t in _CV_TAILS)


@dataclass
class _Held:
    name: str          # canonical
    line: int
    is_cv: bool


@dataclass
class FunctionRecord:
    qual: str
    path: str                       # repo-relative
    line: int
    acquisitions: list = field(default_factory=list)   # (canonical, line)
    direct_edges: list = field(default_factory=list)   # (outer, inner, line)
    lock_calls: list = field(default_factory=list)     # (outer, callee, line)
    blocking: list = field(default_factory=list)       # (desc, line, held|None)
    waits: list = field(default_factory=list)  # (cv, line, predicated, other)
    calls: set = field(default_factory=set)            # callee name keys


@dataclass
class ModuleScan:
    path: str
    abspath: str
    records: list = field(default_factory=list)
    # lineno -> canonical lock name, for the runtime sanitizer's
    # acquisition-site table
    lock_sites: dict = field(default_factory=dict)


def _blocking_desc(node: ast.Call) -> Optional[str]:
    """Classify a call as a blocking operation (or None)."""
    chain = call_name(node)
    if chain is None:
        return None
    tail = chain[-1]
    if chain[-2:] == ("time", "sleep") or chain == ("sleep",):
        return "time.sleep"
    if tail == "join" and len(chain) >= 2 and "path" not in chain \
            and "os" not in chain:
        # thread/process join; str.join on a constant receiver never
        # forms a Name chain, and iterable-building args mark str.join
        if not any(isinstance(a, (ast.GeneratorExp, ast.ListComp,
                                  ast.Constant)) for a in node.args):
            return f"{'.'.join(chain)}() join"
    if tail == "result" and len(chain) >= 2:
        return f"{'.'.join(chain)}() (future/handle result)"
    if tail == "get" and len(chain) >= 2 and not node.args:
        kw = {k.arg for k in node.keywords}
        if "timeout" not in kw:
            return f"{'.'.join(chain)}() without timeout"
    if tail == "wait" and len(chain) >= 2 and not node.args:
        kw = {k.arg for k in node.keywords}
        if "timeout" not in kw and not _is_lock_chain(chain[:-1] or chain):
            return f"{'.'.join(chain)}() without timeout"
    return None


def scan_module(path: Path, root: Path) -> Optional[ModuleScan]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None
    pm = ParentMap(tree)
    scan = ModuleScan(path=rel_path(path, root),
                      abspath=str(path.resolve()))

    funcs = [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]
    for fn in funcs:
        cls = enclosing_class_name(pm, fn)
        rec = FunctionRecord(qual=qualname_of(pm, fn), path=scan.path,
                             line=fn.lineno)
        _walk(fn, rec, cls, pm, scan, held=[])
        scan.records.append(rec)
    return scan


def _resolve_lock(node: ast.expr, cls: Optional[str]) -> Optional[_Held]:
    chain = attr_chain(node)
    if chain is None or not _is_lock_chain(chain):
        return None
    name = hierarchy.canonical_lock_name(chain, cls)
    return _Held(name=name, line=node.lineno,
                 is_cv=hierarchy.is_condition_name(name, chain[-1]))


def _walk(node: ast.AST, rec: FunctionRecord, cls: Optional[str],
          pm: ParentMap, scan: ModuleScan, held: list) -> None:
    """Statement walk tracking the held-lock stack; does not descend
    into nested function/lambda bodies (they execute later)."""
    for child in ast.iter_child_nodes(node):
        _walk_stmt(child, rec, cls, pm, scan, held)


def _handle_with(child: ast.With, rec: FunctionRecord, cls: Optional[str],
                 pm: ParentMap, scan: ModuleScan, held: list) -> None:
    pushed = 0
    for item in child.items:
        lk = _resolve_lock(item.context_expr, cls)
        if lk is None:
            # non-lock context manager: still scan its expr for calls
            _walk_stmt(item.context_expr, rec, cls, pm, scan, held)
            continue
        rec.acquisitions.append((lk.name, lk.line))
        scan.lock_sites.setdefault(item.context_expr.lineno, lk.name)
        if held:
            rec.direct_edges.append((held[-1].name, lk.name, lk.line))
        held.append(lk)
        pushed += 1
    for stmt in child.body:
        _walk_stmt(stmt, rec, cls, pm, scan, held)
    for _ in range(pushed):
        held.pop()


def _walk_stmt(child: ast.AST, rec: FunctionRecord, cls: Optional[str],
               pm: ParentMap, scan: ModuleScan, held: list) -> None:
    if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
        return
    if isinstance(child, ast.With):
        _handle_with(child, rec, cls, pm, scan, held)
        return
    if isinstance(child, ast.Call):
        _handle_call(child, rec, cls, pm, scan, held)
    _walk(child, rec, cls, pm, scan, held)


def _handle_call(node: ast.Call, rec: FunctionRecord, cls: Optional[str],
                 pm: ParentMap, scan: ModuleScan, held: list) -> None:
    chain = call_name(node)
    holder = held[-1].name if held else None

    if chain is not None:
        tail = chain[-1]
        # explicit .acquire() on a lock-like receiver
        if tail == "acquire" and len(chain) >= 2 \
                and _is_lock_chain(chain[:-1]):
            lk = _resolve_lock(node.func.value, cls)
            if lk is not None:
                rec.acquisitions.append((lk.name, node.lineno))
                scan.lock_sites.setdefault(node.lineno, lk.name)
                if holder:
                    rec.direct_edges.append((holder, lk.name, node.lineno))
            return
        # condvar wait: predicate-loop rule + wait-while-holding-other
        if tail in ("wait", "wait_for") and len(chain) >= 2 \
                and _is_lock_chain(chain[:-1]):
            lk = _resolve_lock(node.func.value, cls)
            if lk is not None and lk.is_cv:
                predicated = tail == "wait_for" or _has_predicate_loop(
                    pm, node)
                other = next((h.name for h in reversed(held)
                              if h.name != lk.name), None)
                rec.waits.append((lk.name, node.lineno, predicated, other))
                return

    desc = _blocking_desc(node)
    if desc is not None:
        rec.blocking.append((desc, node.lineno, holder))

    if chain is not None:
        key = chain[-1]
        rec.calls.add(key)
        if holder is not None:
            rec.lock_calls.append((holder, key, node.lineno))


def _has_predicate_loop(pm: ParentMap, node: ast.AST) -> bool:
    for anc in pm.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        if isinstance(anc, ast.While) and not is_constant_true(anc.test):
            return True
    return False


# ------------------------------------------------------------- analysis
@dataclass
class ConcurrencyResult:
    findings: list
    edges: dict            # (outer, inner) -> list of (path, line, note)
    lock_sites: dict       # (abspath, lineno) -> canonical name


def analyze(paths: list[Path], root: Path) -> ConcurrencyResult:
    scans = [s for p in iter_python_files(paths)
             if (s := scan_module(p, root)) is not None]
    records = [r for s in scans for r in s.records]

    # name-indexed "call graph": def name -> records
    by_name: dict[str, list[FunctionRecord]] = {}
    for r in records:
        name = r.qual.rsplit(".", 1)[-1]
        if name not in RESOLUTION_DENYLIST:
            by_name.setdefault(name, []).append(r)

    # fixpoint: locks acquirable during a call to <record>, and whether
    # the call can block (with a witness description)
    locks_of = {r.qual: {a for a, _ in r.acquisitions} for r in records}
    blocks_of = {r.qual: (r.blocking[0][0] if r.blocking else
                          ("waits on " + r.waits[0][0] if r.waits else None))
                 for r in records}
    changed = True
    while changed:
        changed = False
        for r in records:
            for callee in r.calls:
                for tgt in by_name.get(callee, ()):
                    extra = locks_of[tgt.qual] - locks_of[r.qual]
                    if extra:
                        locks_of[r.qual] |= extra
                        changed = True
                    if blocks_of[tgt.qual] and not blocks_of[r.qual]:
                        blocks_of[r.qual] = (f"{callee}() -> "
                                             f"{blocks_of[tgt.qual]}")
                        changed = True

    # assemble the static edge set (direct + through calls)
    edges: dict[tuple[str, str], list] = {}
    for r in records:
        for outer, inner, line in r.direct_edges:
            edges.setdefault((outer, inner), []).append(
                (r.path, line, f"in {r.qual}"))
        for outer, callee, line in r.lock_calls:
            for tgt in by_name.get(callee, ()):
                for inner in locks_of[tgt.qual]:
                    if inner != outer:
                        edges.setdefault((outer, inner), []).append(
                            (r.path, line,
                             f"in {r.qual} via {callee}()"))

    findings: list[Finding] = []
    declared = hierarchy.declared_edge_set()

    # RL004: statically observed edge not in the declared hierarchy
    for (outer, inner), wits in sorted(edges.items()):
        if outer == inner:
            continue    # reported under RL001 when non-reentrant
        if (outer, inner) not in declared:
            path, line, note = wits[0]
            findings.append(Finding(
                "RL004", path, line, note.split()[1],
                f"undeclared lock edge {outer} -> {inner} ({note}); "
                f"declare it in analysis/hierarchy.py or baseline it"))

    # RL001: cycles over declared + observed edges; self-edges on
    # non-reentrant locks count (Conditions are RLock-backed)
    graph: dict[str, set[str]] = {}
    for a, b in list(edges) + list(declared):
        graph.setdefault(a, set()).add(b)
    for (outer, inner), wits in sorted(edges.items()):
        if outer == inner:
            if not hierarchy.is_condition_name(outer, outer.split(".")[-1]):
                path, line, note = wits[0]
                findings.append(Finding(
                    "RL001", path, line, note.split()[1],
                    f"self-acquisition of non-reentrant {outer} ({note})"))
            continue
        if _reaches(graph, inner, outer):
            path, line, note = wits[0]
            findings.append(Finding(
                "RL001", path, line, note.split()[1],
                f"lock-order cycle: edge {outer} -> {inner} ({note}) "
                f"closes a cycle back to {outer}"))

    # RL002: blocking while holding a lock (direct + through calls)
    for r in records:
        for desc, line, holder in r.blocking:
            if holder is not None:
                findings.append(Finding(
                    "RL002", r.path, line, r.qual,
                    f"blocking {desc} while holding {holder}"))
        for cv, line, _pred, other in r.waits:
            if other is not None:
                findings.append(Finding(
                    "RL002", r.path, line, r.qual,
                    f"waiting on {cv} while holding {other} "
                    f"(wait only releases {cv})"))
        for outer, callee, line in r.lock_calls:
            for tgt in by_name.get(callee, ()):
                why = blocks_of[tgt.qual]
                if why and not locks_of[tgt.qual]:
                    # calls that also take locks are covered by the edge
                    # rules; pure-blocking callees are flagged here
                    findings.append(Finding(
                        "RL002", r.path, line, r.qual,
                        f"call to {callee}() may block while holding "
                        f"{outer}: {why}"))
                    break

    # RL003: condvar wait without a predicate loop
    for r in records:
        for cv, line, predicated, _other in r.waits:
            if not predicated:
                findings.append(Finding(
                    "RL003", r.path, line, r.qual,
                    f"{cv}.wait() is not governed by a predicate loop"))

    sites = {}
    for s in scans:
        for line, name in s.lock_sites.items():
            sites[(s.abspath, line)] = name
    return ConcurrencyResult(findings=findings, edges=edges,
                             lock_sites=sites)


def _reaches(graph: dict, src: str, dst: str) -> bool:
    seen, stack = set(), [src]
    while stack:
        cur = stack.pop()
        if cur == dst:
            return True
        if cur in seen:
            continue
        seen.add(cur)
        stack.extend(graph.get(cur, ()))
    return False


def collect_lock_sites(paths: list[Path], root: Path) -> dict:
    """(abspath, lineno) -> canonical lock name, for the sanitizer."""
    return analyze(paths, root).lock_sites


def static_edge_names(paths: list[Path], root: Path) -> set:
    """Name-level static edge set, for runtime cross-validation."""
    return set(analyze(paths, root).edges)
