"""Small AST helpers shared by the reprolint passes."""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator, Optional

# Directories never scanned by default: fixture snippets are deliberate
# rule violations, caches are noise.
DEFAULT_EXCLUDED_DIRS = {"__pycache__", "analysis_fixtures", ".git",
                         ".pytest_cache", "build"}


def iter_python_files(paths: list[Path],
                      exclude_dirs: Optional[set] = None) -> Iterator[Path]:
    """Yield .py files under ``paths`` (files pass through verbatim)."""
    excl = DEFAULT_EXCLUDED_DIRS if exclude_dirs is None else exclude_dirs
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in excl for part in f.parts):
                    yield f


def attr_chain(node: ast.expr) -> Optional[tuple[str, ...]]:
    """``self.kv.lock`` -> ('self', 'kv', 'lock'); None if not a plain
    Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[tuple[str, ...]]:
    """The called expression as a chain, e.g. ``time.sleep(x)`` ->
    ('time', 'sleep')."""
    return attr_chain(node.func)


def names_in(node: ast.AST) -> set[str]:
    """All bare identifiers mentioned anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def is_constant_true(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and bool(node.value) is True


class ParentMap:
    """child -> parent links for one module tree (ast has none)."""

    def __init__(self, tree: ast.AST):
        self._parent: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parent[child] = parent

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parent.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parent.get(node)
        while cur is not None:
            yield cur
            cur = self._parent.get(cur)


def enclosing_function(pm: ParentMap, node: ast.AST):
    for anc in pm.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def qualname_of(pm: ParentMap, node: ast.AST) -> str:
    """``Class.method`` / ``func`` / ``<module>`` for any node."""
    parts: list[str] = []
    for anc in pm.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            parts.append(anc.name)
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        parts.insert(0, node.name)
    return ".".join(reversed(parts)) if parts else "<module>"


def enclosing_class_name(pm: ParentMap, node: ast.AST) -> Optional[str]:
    for anc in pm.ancestors(node):
        if isinstance(anc, ast.ClassDef):
            return anc.name
    return None


def rel_path(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()
