"""JIT-safety checker (RJ101-RJ103).

Three rules, all heuristic but tuned to this repo's idioms:

* RJ101 — host syncs inside jit-traced code. Roots are ``@jax.jit``
  (or ``@partial(jax.jit, ...)``) functions, lambdas wrapped in
  ``jax.jit(...)`` (the ``PagedJitKit`` programs), and everything they
  reach through the name-indexed call graph. ``.item()``, ``np.*``
  conversions, ``float()/int()`` on non-shape values and
  ``device_get/block_until_ready`` force a device round-trip per trace.

* RJ102 — jit closures over mutable state: a wrapped lambda/function
  capturing a name that is reassigned after the wrap (or a loop
  variable) traces one value and silently ignores the rebind.

* RJ103 — unbucketed jit call sites. A call to a known-jitted callable
  whose arguments build arrays with request-dependent extents
  (``asarray`` of a dynamic sequence, ``zeros/full`` with a dynamic
  shape, open ``arange``) compiles a new program per distinct extent.
  Extents are considered SAFE when they flow through an identifier
  mentioning ``bucket``/``pad`` (the runner's ladder idiom), come from
  ``self``/config attributes, literals, or ``x.shape`` (trace-static).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

from repro.analysis.astutils import (ParentMap, attr_chain, call_name,
                                     enclosing_function, iter_python_files,
                                     qualname_of, rel_path)
from repro.analysis.concurrency import RESOLUTION_DENYLIST
from repro.analysis.findings import Finding

_NUMPY_ROOTS = {"np", "jnp", "numpy", "onp"}
_HOST_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_ARRAY_CTORS = {"asarray", "array"}
_SHAPE_CTORS = {"zeros", "ones", "full", "empty"}


def _is_jit_expr(node: ast.expr) -> bool:
    """``jax.jit(...)`` / ``jit(...)`` / ``partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return False
    chain = call_name(node)
    if chain and chain[-1] == "jit":
        return True
    if chain and chain[-1] == "partial" and node.args:
        inner = attr_chain(node.args[0])
        return bool(inner and inner[-1] == "jit")
    return False


@dataclass
class _Module:
    path: str
    tree: ast.AST
    pm: ParentMap


@dataclass
class JitIndex:
    """Names bound to jitted callables, plus traced-root function
    bodies (for the RJ101 scan)."""
    jitted_tails: set = field(default_factory=set)
    roots: list = field(default_factory=list)   # (module, node, qual)


def _build_index(mods: list[_Module]) -> JitIndex:
    idx = JitIndex()
    assigns = []    # (lhs_tail, rhs_tail) for alias propagation
    for m in mods:
        for node in ast.walk(m.tree):
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    if _is_jit_expr(dec) or (
                            (c := attr_chain(dec)) and c[-1] == "jit"):
                        idx.jitted_tails.add(node.name)
                        idx.roots.append((m, node,
                                          qualname_of(m.pm, node)))
            if isinstance(node, ast.Assign) and _is_jit_expr(node.value):
                for tgt in node.targets:
                    chain = attr_chain(tgt)
                    if chain:
                        idx.jitted_tails.add(chain[-1])
                wrapped = node.value.args[0] if node.value.args else None
                if isinstance(wrapped, ast.Lambda):
                    idx.roots.append((m, wrapped,
                                      qualname_of(m.pm, node)))
                elif wrapped is not None and \
                        (wc := attr_chain(wrapped)) is not None:
                    idx.jitted_tails.add(wc[-1])
            elif isinstance(node, ast.Assign):
                # plain aliases, incl. guarded ones:
                # self._inject_fn = kit.pool_inject if kit else None
                rhs_exprs = [node.value]
                if isinstance(node.value, ast.IfExp):
                    rhs_exprs = [node.value.body, node.value.orelse]
                for rv in rhs_exprs:
                    rhs = attr_chain(rv)
                    if rhs:
                        for tgt in node.targets:
                            lhs = attr_chain(tgt)
                            if lhs:
                                assigns.append((lhs[-1], rhs[-1]))
    # propagate jittedness through plain alias assignments
    # (self._step = kit.decode_step)
    changed = True
    while changed:
        changed = False
        for lhs, rhs in assigns:
            if rhs in idx.jitted_tails and lhs not in idx.jitted_tails:
                idx.jitted_tails.add(lhs)
                changed = True
    return idx


# ------------------------------------------------------ RJ101 host sync
def _host_sync_hits(body_nodes) -> list[tuple[int, str]]:
    hits = []
    for node in body_nodes:
        if not isinstance(node, ast.Call):
            continue
        chain = call_name(node)
        if chain is None:
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _HOST_SYNC_ATTRS:
                hits.append((node.lineno, f".{node.func.attr}()"))
            continue
        tail = chain[-1]
        if tail in _HOST_SYNC_ATTRS and len(chain) >= 2:
            hits.append((node.lineno, f".{tail}()"))
        elif chain[0] in ("np", "numpy", "onp") and tail in (
                _ARRAY_CTORS | _SHAPE_CTORS | {"concatenate", "stack"}):
            hits.append((node.lineno,
                         f"{'.'.join(chain)}() materializes on host"))
        elif chain[-2:] == ("jax", "device_get") or tail == "device_get":
            hits.append((node.lineno, "jax.device_get()"))
        elif chain == ("float",) or chain == ("int",):
            # only direct casts of a value (not arithmetic over config
            # scalars, which is trace-static)
            arg = node.args[0] if node.args else None
            if isinstance(arg, (ast.Name, ast.Attribute, ast.Subscript)) \
                    and not _shape_like(arg):
                hits.append((node.lineno, f"{tail}() on a traced value"))
    return hits


def _shape_like(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant):
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in ("shape",
                                                           "ndim", "size"):
            return True
        if isinstance(sub, ast.Call):
            c = call_name(sub)
            if c and c[-1] == "len":
                return True
    return False


def _body_calls(node) -> set[str]:
    """Callee names a traced body can reach. Only bare-name calls and
    module-qualified ``mod.func(...)`` count — method dispatch
    (``self.x()``/``obj.m()``) does not propagate tracedness, since
    generic method tails (``step``/``execute``) would otherwise smear
    the traced set over the whole host-side engine."""
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            c = call_name(sub)
            if c and len(c) <= 2 and c[0] not in ("self", "cls") \
                    and c[-1] not in RESOLUTION_DENYLIST:
                out.add(c[-1])
    return out


# --------------------------------------------------- RJ103 shape flow
class _ScopeInfo:
    """Per-function dataflow for the dynamic-extent heuristic."""

    def __init__(self, fn: Optional[ast.AST]):
        self.params: set[str] = set()
        self.rhs: dict[str, list[ast.expr]] = {}
        self.dict_items: dict[str, list[ast.expr]] = {}
        self.bucketed: set[str] = set()
        if fn is None or isinstance(fn, ast.Lambda):
            return
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if a.arg not in ("self", "cls"):
                self.params.add(a.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.rhs.setdefault(tgt.id, []).append(node.value)
                        if _mentions_bucket(node.value):
                            self.bucketed.add(tgt.id)
                        if isinstance(node.value, ast.Dict):
                            self.dict_items.setdefault(tgt.id, []).extend(
                                v for v in node.value.values
                                if v is not None)
                    elif isinstance(tgt, ast.Subscript) and \
                            isinstance(tgt.value, ast.Name):
                        self.dict_items.setdefault(
                            tgt.value.id, []).append(node.value)
            elif isinstance(node, (ast.Tuple, ast.List)):
                pass
        # bucketedness flows through assignments: a value computed from
        # a bucketed/padded value is itself extent-stable
        changed = True
        while changed:
            changed = False
            for name, exprs in self.rhs.items():
                if name in self.bucketed:
                    continue
                for e in exprs:
                    if {n for n, _ in _names_skipping_shape(e)} \
                            & self.bucketed:
                        self.bucketed.add(name)
                        changed = True
                        break

    def is_dynamic(self, expr: ast.expr, _depth: int = 0) -> bool:
        """Does this expression's VALUE depend on request-sized data?
        (``.shape`` chains are trace-static; bucketed locals are safe.)"""
        if _depth > 4:
            return False
        for name, chain in _names_skipping_shape(expr):
            if name in ("self", "cls"):
                continue
            if name in self.bucketed:
                continue
            if name in self.params:
                return True
            for r in self.rhs.get(name, ()):
                if self.is_dynamic(r, _depth + 1):
                    return True
        return False


def _mentions_bucket(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        ident = None
        if isinstance(sub, ast.Name):
            ident = sub.id
        elif isinstance(sub, ast.Attribute):
            ident = sub.attr
        elif isinstance(sub, ast.FunctionDef):
            ident = sub.name
        if ident and ("bucket" in ident.lower() or "pad" in ident.lower()):
            return True
    return False


def _names_skipping_shape(expr: ast.expr):
    """Yield (root name, chain) for identifier chains under ``expr``,
    skipping any subtree under an ``x.shape``/``len()``-style access
    (those are static at trace boundaries)."""
    out = []

    def visit(node):
        if isinstance(node, ast.Attribute) and node.attr in ("shape",
                                                             "ndim",
                                                             "dtype"):
            return
        chain = attr_chain(node) if isinstance(
            node, (ast.Attribute, ast.Name)) else None
        if chain is not None:
            out.append((chain[0], chain))
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def _ctor_findings(scope: _ScopeInfo, expr: ast.expr) -> list[tuple[int,
                                                                    str]]:
    """Dynamic-extent array constructors inside one argument expr."""
    hits = []
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        chain = call_name(node)
        if not chain or len(chain) < 2 or chain[0] not in _NUMPY_ROOTS:
            continue
        tail = chain[-1]
        if tail in _ARRAY_CTORS and node.args:
            arg = node.args[0]
            if isinstance(arg, (ast.List, ast.Tuple, ast.Constant)):
                continue            # literal structure: fixed length
            if scope.is_dynamic(arg):
                hits.append((node.lineno,
                             f"{'.'.join(chain)}() over a request-sized "
                             f"sequence"))
        elif tail in _SHAPE_CTORS and node.args:
            shape = node.args[0]
            if scope.is_dynamic(shape):
                hits.append((node.lineno,
                             f"{'.'.join(chain)}() with a dynamic shape"))
        elif tail == "arange":
            if _arange_dynamic(scope, node):
                hits.append((node.lineno,
                             f"{'.'.join(chain)}() with a dynamic length"))
    return hits


def _arange_dynamic(scope: _ScopeInfo, node: ast.Call) -> bool:
    args = node.args
    if not args:
        return False
    if len(args) == 1:
        return scope.is_dynamic(args[0])
    start, stop = args[0], args[1]
    # arange(t0, t0 + C): length C — static iff C is
    if isinstance(stop, ast.BinOp) and isinstance(stop.op, ast.Add):
        if ast.dump(stop.left) == ast.dump(start):
            return scope.is_dynamic(stop.right)
        if ast.dump(stop.right) == ast.dump(start):
            return scope.is_dynamic(stop.left)
    return scope.is_dynamic(start) or scope.is_dynamic(stop)


# -------------------------------------------------------------- analyze
def analyze(paths: list[Path], root: Path) -> list[Finding]:
    mods = []
    for p in iter_python_files(paths):
        try:
            tree = ast.parse(p.read_text(), filename=str(p))
        except SyntaxError:
            continue
        mods.append(_Module(path=rel_path(p, root), tree=tree,
                            pm=ParentMap(tree)))
    idx = _build_index(mods)
    findings: list[Finding] = []

    # RJ101: host syncs in traced roots + everything they call (one
    # fixpoint over the name-indexed call graph)
    # tracedness only propagates into module-level functions (methods
    # are host-side orchestration in this codebase; traced helpers are
    # free functions in dense/kernels/optim)
    defs_by_name: dict[str, list] = {}
    for m in mods:
        for node in m.tree.body:
            if isinstance(node, ast.FunctionDef):
                defs_by_name.setdefault(node.name, []).append((m, node))
    traced: dict[int, tuple] = {id(n): (m, n, q) for m, n, q in idx.roots}
    frontier = list(traced.values())
    while frontier:
        m, node, qual = frontier.pop()
        for callee in _body_calls(node):
            for cm, cnode in defs_by_name.get(callee, ()):
                if id(cnode) not in traced:
                    cq = qualname_of(cm.pm, cnode)
                    traced[id(cnode)] = (cm, cnode, cq)
                    frontier.append((cm, cnode, cq))
    for m, node, qual in traced.values():
        body = list(ast.walk(node))
        for line, desc in _host_sync_hits(body):
            findings.append(Finding(
                "RJ101", m.path, line, qual,
                f"host sync in jit-traced code: {desc}"))

    # RJ102: mutable/loop captures in jit wraps
    for m in mods:
        for node in ast.walk(m.tree):
            if not (isinstance(node, ast.Call) and _is_jit_expr(node)
                    and node.args):
                continue
            wrapped = node.args[0]
            if not isinstance(wrapped, ast.Lambda):
                continue
            fn = enclosing_function(m.pm, node)
            if fn is None:
                continue
            params = {a.arg for a in wrapped.args.args}
            captured = {n.id for n in ast.walk(wrapped.body)
                        if isinstance(n, ast.Name)} - params
            qual = qualname_of(m.pm, fn)
            for cap in sorted(captured):
                for sub in ast.walk(fn):
                    if isinstance(sub, (ast.Assign, ast.AugAssign)) \
                            and sub.lineno > node.lineno:
                        tgts = sub.targets if isinstance(
                            sub, ast.Assign) else [sub.target]
                        if any(isinstance(t, ast.Name) and t.id == cap
                               for t in tgts):
                            findings.append(Finding(
                                "RJ102", m.path, node.lineno, qual,
                                f"jit lambda captures '{cap}' which is "
                                f"reassigned at line {sub.lineno} (the "
                                f"trace freezes the old value)"))
                            break
                for anc in m.pm.ancestors(node):
                    if isinstance(anc, ast.For) and \
                            isinstance(anc.target, ast.Name) and \
                            anc.target.id == cap:
                        findings.append(Finding(
                            "RJ102", m.path, node.lineno, qual,
                            f"jit lambda captures loop variable '{cap}' "
                            f"(every wrap traces the same last value)"))

    # RJ103: unbucketed shape inputs at jit call sites
    for m in mods:
        for node in ast.walk(m.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = call_name(node)
            if not chain or chain[-1] not in idx.jitted_tails:
                continue
            if _is_jit_expr(node):
                continue            # the wrap itself, not a call
            fn = enclosing_function(m.pm, node)
            scope = _ScopeInfo(fn)
            qual = qualname_of(m.pm, fn) if fn is not None else "<module>"
            exprs = list(node.args) + [k.value for k in node.keywords]
            seen_names = set()
            expanded = []
            for e in exprs:
                expanded.append(e)
                if isinstance(e, ast.Name) and e.id not in seen_names:
                    seen_names.add(e.id)
                    expanded.extend(scope.dict_items.get(e.id, ()))
                    expanded.extend(scope.rhs.get(e.id, ()))
            reported = set()
            for e in expanded:
                for line, desc in _ctor_findings(scope, e):
                    if (line, desc) in reported:
                        continue
                    reported.add((line, desc))
                    findings.append(Finding(
                        "RJ103", m.path, line, qual,
                        f"jit call to '{chain[-1]}' with unbucketed "
                        f"shape input: {desc} (compiles per extent)"))
    return findings
