"""Declared lock hierarchy for the serving layer.

This registry is the single source of truth for BOTH checkers: the
static concurrency pass (cycles, undeclared edges) and the runtime
lock-order sanitizer assert observed acquisition edges against it.

An edge ``(outer, inner)`` declares that a thread may acquire ``inner``
while holding ``outer``. The graph must stay acyclic — adding an edge
that closes a cycle is a design bug, not a registry update.

Canonical names: lock attribute expressions are mapped to short stable
names (``self._done_cv`` -> ``engine.done_cv``) so the same lock is one
node regardless of which alias reaches it. Locks the registry does not
know are auto-named ``<Class>.<attr>`` — nesting them immediately
surfaces as an undeclared edge (RL004), which forces either a registry
entry here or a justified baseline entry.
"""
from __future__ import annotations

from typing import Optional

# ---------------------------------------------------------------- names
# (enclosing class, attribute) -> canonical name. Most precise rule,
# wins over the tail rules below.
CLASS_ALIASES: dict[tuple[str, str], str] = {
    ("EngineBase", "_done_cv"): "engine.done_cv",
    ("ServeRequest", "_cv"): "request.cv",
    ("ServeStats", "lock"): "stats.lock",
    ("PagedKVState", "lock"): "kv.lock",
    ("PagedKVState", "pool_lock"): "kv.pool_lock",
    ("EncodeStage", "_lock"): "encode.lock",
    ("PsiEP", "_lock"): "psi_ep.lock",
    # streaming ψ_EP (encode–prefill overlap): a LEAF — publish/fill/
    # span_ready never take another lock, and PsiEP.add_shard publishes
    # OUTSIDE psi_ep.lock, so no edge involves it
    ("ShardStream", "_lock"): "shard_stream.lock",
    ("MMTokenCache", "_lock"): "mm_cache.lock",
    ("LoadBalancer", "_lock"): "lb.lock",
    ("LBTicket", "_lock"): "ticket.lock",
    ("LoadEstimator", "_lock"): "load_estimator.lock",
    ("InstanceWorker", "_mig_lock"): "instance.mig_lock",
    ("FakeEngine", "_lock"): "fake_engine.lock",
}

# (owner attribute, lock attribute) -> canonical name, for access from
# outside the owning class: ``self.kv.lock`` / ``inst._stats.lock``.
OWNER_ALIASES: dict[tuple[str, str], str] = {
    ("kv", "lock"): "kv.lock",
    ("_kv", "lock"): "kv.lock",
    ("kv", "pool_lock"): "kv.pool_lock",
    ("_kv", "pool_lock"): "kv.pool_lock",
    ("stats", "lock"): "stats.lock",
    ("_stats", "lock"): "stats.lock",
}

# Unambiguous attribute tails (one lock repo-wide bears the name).
TAIL_ALIASES: dict[str, str] = {
    "_done_cv": "engine.done_cv",
    "_cv": "request.cv",
    "_mm_lock": "engine.mm_lock",
    "_mig_lock": "instance.mig_lock",
    "pool_lock": "kv.pool_lock",
}

#: canonical names known to be Conditions (RL003 predicate-loop rule
#: applies; Locks and Events are exempt).
CONDITIONS: set[str] = {"engine.done_cv", "request.cv"}

# ---------------------------------------------------------------- edges
#: Declared acquisition order (outer may hold while taking inner), with
#: the code site that motivates each edge.
EDGES: list[tuple[str, str]] = [
    # engine._finish/_fail/abort: _collect takes _done_cv, and the lock
    # order is _done_cv -> req._cv everywhere (serving/engine.py).
    ("engine.done_cv", "request.cv"),
    # paged stages account pool pressure while holding the block-manager
    # lock: stage admission + KVBlockManager's on_stat=stats.bump
    # callback (serving/stages.py), cluster migration admit
    # (serving/cluster.py).
    ("kv.lock", "stats.lock"),
    # EngineBase.submit bumps mm-cache hit counters inside the in-flight
    # dedup critical section (serving/engine.py).
    ("engine.mm_lock", "stats.lock"),
    # EngineBase.submit advances a dedup waiter to ENCODING while
    # holding the in-flight registry lock (serving/engine.py);
    # request.cv is a leaf, nothing is acquired under it.
    ("engine.mm_lock", "request.cv"),
    # PagedDecodeStage._prepare preempts a slot (reset_generation takes
    # the request condvar) inside the pool critical section
    # (serving/stages.py).
    ("kv.lock", "request.cv"),
]


def canonical_lock_name(chain: tuple[str, ...],
                        enclosing_class: Optional[str]) -> str:
    """Map a lock attribute chain to its canonical node name.

    Resolution order: class-qualified, owner-qualified, unambiguous
    tail, then the ``<Class>.<attr>`` auto-name fallback.
    """
    tail = chain[-1]
    # self.X inside a registered class
    if enclosing_class and len(chain) == 2 and chain[0] in ("self", "cls"):
        hit = CLASS_ALIASES.get((enclosing_class, tail))
        if hit:
            return hit
    if len(chain) >= 2:
        hit = OWNER_ALIASES.get((chain[-2], tail))
        if hit:
            return hit
    hit = TAIL_ALIASES.get(tail)
    if hit:
        return hit
    owner = enclosing_class or (chain[-2] if len(chain) >= 2 else chain[0])
    if owner in ("self", "cls"):
        owner = enclosing_class or "self"
    return f"{owner}.{tail}"


def is_condition_name(canonical: str, raw_tail: str) -> bool:
    """Conditions get the RL003 predicate rule; recognize registered
    names plus the repo's ``*_cv``/``*cond*`` naming convention."""
    if canonical in CONDITIONS:
        return True
    t = raw_tail.lower()
    return t.endswith("_cv") or t == "cv" or "cond" in t


def declared_edge_set() -> set[tuple[str, str]]:
    return set(EDGES)


def hierarchy_graph() -> dict[str, set[str]]:
    g: dict[str, set[str]] = {}
    for a, b in EDGES:
        g.setdefault(a, set()).add(b)
    return g
