"""Finding record + the registry of keyed rules reprolint can emit."""
from __future__ import annotations

from dataclasses import dataclass, field

#: key -> one-line rule description (the README table is generated from
#: the same text; keep these short and declarative).
FINDING_KEYS: dict[str, str] = {
    "RL001": "lock-order cycle: acquisition edges form a cycle with the "
             "declared hierarchy",
    "RL002": "blocking call while holding a lock (sleep/join/result/"
             "untimed queue.get, or waiting on a condvar while holding "
             "a different lock)",
    "RL003": "condvar wait() not governed by a predicate loop "
             "(wakeups are advisory; waits must re-check their condition)",
    "RL004": "lock acquisition edge not declared in the hierarchy "
             "registry (declare it in analysis/hierarchy.py or baseline it)",
    "RJ101": "host sync inside jit-traced code (.item()/np.asarray/"
             "float()/int() on tracers forces a device round-trip)",
    "RJ102": "jit closure captures a mutable/reassigned variable "
             "(the trace freezes the value; later rebinds are ignored)",
    "RJ103": "jit call site with shape inputs that do not flow through "
             "a bucket ladder (every new extent compiles a new program)",
}


@dataclass
class Finding:
    """One analyzer hit, keyed and locatable.

    ``symbol`` is the enclosing ``Class.method`` / function (or
    ``<module>``) — baseline entries match on (key, path, symbol) so
    they survive line-number churn.
    """
    key: str
    path: str            # repo-relative, forward slashes
    line: int
    symbol: str
    message: str
    extra: dict = field(default_factory=dict)

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.key} [{self.symbol}] " \
               f"{self.message}"

    @property
    def baseline_id(self) -> tuple[str, str, str]:
        return (self.key, self.path, self.symbol)
