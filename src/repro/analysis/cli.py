"""reprolint CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (or fully baselined), 1 active findings or broken
baseline, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis import concurrency, jit_safety
from repro.analysis.findings import FINDING_KEYS, Finding


def repo_root() -> Path:
    """src/repro/analysis/cli.py -> repo root (three parents up from
    the package)."""
    return Path(__file__).resolve().parents[3]


def analyze_paths(paths: list[Path], root: Path) -> list[Finding]:
    """Run all static passes; sorted, deduplicated findings."""
    findings = list(concurrency.analyze(paths, root).findings)
    findings += jit_safety.analyze(paths, root)
    uniq = {}
    for f in findings:
        uniq.setdefault((f.key, f.path, f.line, f.message), f)
    return sorted(uniq.values(), key=lambda f: (f.path, f.line, f.key))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific concurrency & JIT-safety lint")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to scan (default: src tests)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: <root>/"
                         f"{baseline_mod.DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as baseline entries "
                         "(with TODO justifications) and exit")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (show everything)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--keys", action="store_true",
                    help="print the finding-key table and exit")
    args = ap.parse_args(argv)

    if args.keys:
        for key, desc in FINDING_KEYS.items():
            print(f"{key}  {desc}")
        return 0

    root = repo_root()
    paths = [Path(p) if Path(p).is_absolute() else root / p
             for p in args.paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path(s): {missing}", file=sys.stderr)
        return 2

    findings = analyze_paths(paths, root)

    bpath = Path(args.baseline) if args.baseline else \
        root / baseline_mod.DEFAULT_BASELINE
    if args.write_baseline:
        baseline_mod.write(bpath, findings)
        print(f"wrote {len(findings)} finding(s) to {bpath} — fill in "
              f"the 'why' fields before committing")
        return 0

    suppressed, stale = [], []
    if not args.no_baseline:
        try:
            entries = baseline_mod.load(bpath)
        except baseline_mod.BaselineError as e:
            print(f"baseline error: {e}", file=sys.stderr)
            return 1
        findings, suppressed, stale = baseline_mod.apply(findings, entries)

    if args.format == "json":
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        for e in stale:
            print(f"warning: stale baseline entry {e['key']} "
                  f"{e['path']}:{e['symbol']} (no matching finding)",
                  file=sys.stderr)
        tail = f"{len(findings)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} baselined"
        if stale:
            tail += f", {len(stale)} stale baseline entr(ies)"
        print(f"reprolint: {tail}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
