"""Runtime lock-order sanitizer (opt-in: ``REPRO_LOCK_SANITIZER=1``).

Patches ``threading.Lock/RLock/Condition`` so every acquisition records
a per-thread stack. Acquisition SITES (file, line) are mapped to the
same canonical lock names the static pass uses — the site table is
built by running :mod:`repro.analysis.concurrency` over the repo at
install time — and every named->named nesting becomes an observed
edge. An edge that closes a cycle against the declared hierarchy
(:mod:`repro.analysis.hierarchy`) plus everything witnessed so far is a
violation: recorded always, raised immediately when
``REPRO_LOCK_SANITIZER=raise``.

Locks acquired at unnamed sites (queue internals, executors) are
tracked for nesting but produce no edges, so third-party machinery adds
no noise. ``dump()`` writes the witnessed name-level graph for
cross-validation against the static edge set
(``tests/test_analysis_crossval.py``).

Install BEFORE the serving modules create their locks (the pytest hook
in ``tests/conftest.py`` does this at collection time); locks created
earlier stay unpatched and invisible, which is the right default for
jax/stdlib internals.
"""
from __future__ import annotations

import json
import os
import sys
import threading
from pathlib import Path

import _thread

_REAL_LOCK = _thread.allocate_lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition

_SKIP_SUBSTRINGS = (
    os.sep + "threading.py",
    os.sep + "queue.py",
    os.sep + "lock_sanitizer.py",
    "concurrent" + os.sep + "futures",
    os.sep + "_weakrefset.py",
)


class LockOrderViolation(RuntimeError):
    pass


class _ThreadState(threading.local):
    def __init__(self):
        self.stack = []        # [(obj_id, name_or_None)]
        self.depth = {}        # obj_id -> reentry count


class Sanitizer:
    """Shared sanitizer state: site names, witnessed graph, violations."""

    def __init__(self, site_names: dict, declared_edges: set,
                 raise_on_violation: bool = False):
        self.site_names = site_names          # (abspath, line) -> name
        self.declared = set(declared_edges)
        self.graph: dict[str, set] = {}
        for a, b in self.declared:
            self.graph.setdefault(a, set()).add(b)
        self.witnessed: set = set()           # (outer, inner)
        self.violations: list[str] = []
        self.acquisitions = 0
        self._meta = _REAL_LOCK()             # leaf; guards graph state
        self._tls = _ThreadState()
        self.raise_on_violation = raise_on_violation

    # ---------------------------------------------------------- events
    def _site_name(self):
        f = sys._getframe(2)
        while f is not None:
            fname = f.f_code.co_filename
            if not any(s in fname for s in _SKIP_SUBSTRINGS):
                return self.site_names.get(
                    (os.path.abspath(fname), f.f_lineno))
            f = f.f_back
        return None

    def on_acquired(self, obj) -> None:
        tls = self._tls
        oid = id(obj)
        tls.depth[oid] = tls.depth.get(oid, 0) + 1
        if tls.depth[oid] > 1:
            return                             # reentrant re-acquire
        name = self._site_name()
        self._record_push(oid, name)

    def _record_push(self, oid, name) -> None:
        tls = self._tls
        self.acquisitions += 1
        if name is not None:
            holder = next((n for _o, n in reversed(tls.stack)
                           if n is not None and n != name), None)
            if holder is not None:
                self._record_edge(holder, name)
        tls.stack.append((oid, name))

    def _record_edge(self, outer: str, inner: str) -> None:
        with self._meta:
            if (outer, inner) in self.witnessed:
                return
            if self._reaches(inner, outer):
                msg = (f"lock-order violation: acquiring {inner} while "
                       f"holding {outer} closes a cycle against the "
                       f"declared+witnessed hierarchy")
                self.violations.append(msg)
                if self.raise_on_violation:
                    raise LockOrderViolation(msg)
                return
            self.witnessed.add((outer, inner))
            self.graph.setdefault(outer, set()).add(inner)

    def _reaches(self, src: str, dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            cur = stack.pop()
            if cur == dst:
                return True
            if cur not in seen:
                seen.add(cur)
                stack.extend(self.graph.get(cur, ()))
        return False

    def on_released(self, obj) -> None:
        tls = self._tls
        oid = id(obj)
        d = tls.depth.get(oid, 0)
        if d > 1:
            tls.depth[oid] = d - 1
            return
        tls.depth.pop(oid, None)
        for i in range(len(tls.stack) - 1, -1, -1):
            if tls.stack[i][0] == oid:
                del tls.stack[i]
                return

    def suspend(self, obj):
        """Condition.wait releases its lock: pop the entry, return the
        name so resume can re-record the re-acquisition."""
        tls = self._tls
        oid = id(obj)
        name = None
        for i in range(len(tls.stack) - 1, -1, -1):
            if tls.stack[i][0] == oid:
                name = tls.stack[i][1]
                del tls.stack[i]
                break
        depth, tls.depth[oid] = tls.depth.get(oid, 1), 0
        tls.depth.pop(oid, None)
        return name, depth

    def resume(self, obj, saved) -> None:
        name, depth = saved
        tls = self._tls
        tls.depth[id(obj)] = depth
        self._record_push(id(obj), name)

    # --------------------------------------------------------- reports
    def report(self) -> str:
        lines = [f"lock sanitizer: {self.acquisitions} acquisitions, "
                 f"{len(self.witnessed)} witnessed edge(s), "
                 f"{len(self.violations)} violation(s)"]
        lines += [f"  {a} -> {b}" for a, b in sorted(self.witnessed)]
        lines += [f"  VIOLATION: {v}" for v in self.violations]
        return "\n".join(lines)

    def dump(self, path) -> None:
        payload = {
            "edges": sorted(list(e) for e in self.witnessed),
            "declared": sorted(list(e) for e in self.declared),
            "violations": list(self.violations),
            "acquisitions": self.acquisitions,
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")


_ACTIVE: Sanitizer | None = None


class _SanLock:
    """Drop-in ``threading.Lock`` recording acquisition order."""

    __slots__ = ("_lk",)

    def __init__(self):
        self._lk = _REAL_LOCK()

    def acquire(self, blocking=True, timeout=-1):
        got = self._lk.acquire(blocking, timeout)
        if got and _ACTIVE is not None:
            _ACTIVE.on_acquired(self)
        return got

    acquire_lock = acquire

    def release(self):
        if _ACTIVE is not None:
            _ACTIVE.on_released(self)
        self._lk.release()

    release_lock = release

    def locked(self):
        return self._lk.locked()

    def _at_fork_reinit(self):
        self._lk._at_fork_reinit()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def __repr__(self):
        return f"<_SanLock {self._lk!r}>"


class _SanRLock:
    """Drop-in ``threading.RLock`` (reentry collapsed to one entry)."""

    __slots__ = ("_lk",)

    def __init__(self):
        self._lk = _REAL_RLOCK()

    def acquire(self, blocking=True, timeout=-1):
        got = self._lk.acquire(blocking, timeout)
        if got and _ACTIVE is not None:
            _ACTIVE.on_acquired(self)
        return got

    def release(self):
        if _ACTIVE is not None:
            _ACTIVE.on_released(self)
        self._lk.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    # Condition integration (threading.Condition probes for these)
    def _release_save(self):
        if _ACTIVE is not None:
            _ACTIVE.on_released(self)
        return self._lk._release_save()

    def _acquire_restore(self, state):
        self._lk._acquire_restore(state)
        if _ACTIVE is not None:
            _ACTIVE.on_acquired(self)

    def _is_owned(self):
        return self._lk._is_owned()

    def _at_fork_reinit(self):
        self._lk._at_fork_reinit()

    def __repr__(self):
        return f"<_SanRLock {self._lk!r}>"


class _SanCondition(_REAL_CONDITION):
    """``threading.Condition`` tracking itself as one lock node.

    The default inner lock stays a REAL RLock (the condvar is the
    tracked entity; double-tracking its backing lock would only add an
    unnamed twin entry). Explicitly passed locks — e.g. queue.Queue
    building conditions over its own (patched) mutex — keep whatever
    tracking they already have.
    """

    def __init__(self, lock=None):
        if lock is None:
            lock = _REAL_RLOCK()
        super().__init__(lock)

    def __enter__(self):
        res = super().__enter__()
        if _ACTIVE is not None:
            _ACTIVE.on_acquired(self)
        return res

    def __exit__(self, *exc):
        if _ACTIVE is not None:
            _ACTIVE.on_released(self)
        return super().__exit__(*exc)

    def wait(self, timeout=None):
        saved = _ACTIVE.suspend(self) if _ACTIVE is not None else None
        try:
            return super().wait(timeout)
        finally:
            if _ACTIVE is not None:
                _ACTIVE.resume(self, saved)

    def wait_for(self, predicate, timeout=None):
        # the loop calls self.wait(); per-wait tracking above suffices
        return super().wait_for(predicate, timeout)


def default_site_table() -> dict:
    """(abspath, line) -> canonical name, from the static pass over the
    repo's src/ and tests/ trees."""
    from repro.analysis import concurrency
    root = Path(__file__).resolve().parents[3]
    paths = [p for p in (root / "src", root / "tests") if p.exists()]
    sites = concurrency.collect_lock_sites(paths, root)
    return {(os.path.abspath(f), line): name
            for (f, line), name in sites.items()}


def install(site_table: dict | None = None,
            declared: set | None = None,
            raise_on_violation: bool | None = None) -> Sanitizer:
    """Patch threading and return the active :class:`Sanitizer`."""
    global _ACTIVE
    if _ACTIVE is not None:
        return _ACTIVE
    if site_table is None:
        site_table = default_site_table()
    if declared is None:
        from repro.analysis import hierarchy
        declared = hierarchy.declared_edge_set()
    if raise_on_violation is None:
        raise_on_violation = (os.environ.get("REPRO_LOCK_SANITIZER", "")
                              == "raise")
    _ACTIVE = Sanitizer(site_table, declared,
                        raise_on_violation=raise_on_violation)
    threading.Lock = _SanLock
    threading.RLock = _SanRLock
    threading.Condition = _SanCondition
    return _ACTIVE


def uninstall() -> None:
    """Restore threading factories. Already-created sanitized locks
    keep working but stop recording."""
    global _ACTIVE
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    _ACTIVE = None


def active() -> Sanitizer | None:
    return _ACTIVE


def enabled_by_env() -> bool:
    return os.environ.get("REPRO_LOCK_SANITIZER", "") not in ("", "0")
