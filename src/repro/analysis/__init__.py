"""reprolint: repo-specific concurrency & JIT-safety static analysis.

Three passes over ``src/`` and ``tests/``:

* :mod:`repro.analysis.concurrency` — lock/condvar acquisition graph,
  cycle detection against the declared hierarchy
  (:mod:`repro.analysis.hierarchy`), blocking calls under a held lock,
  condvar waits without a predicate loop.
* :mod:`repro.analysis.jit_safety` — host syncs inside jitted code,
  mutable-closure captures, and ``jax.jit`` call sites whose shape
  inputs don't flow through a bucket ladder (recompile risk).
* :mod:`repro.analysis.lock_sanitizer` — opt-in runtime patch of
  ``threading.Lock/RLock/Condition`` (``REPRO_LOCK_SANITIZER=1``) that
  witnesses real acquisition order and asserts it against the same
  declared hierarchy the static pass uses.

Run the CLI with ``python -m repro.analysis [paths...]``; suppress
intentional findings via the checked-in ``analysis_baseline.json``
(every entry carries a justification).
"""
from repro.analysis.findings import FINDING_KEYS, Finding

__all__ = ["Finding", "FINDING_KEYS"]
