"""Baseline suppression for reprolint findings.

The baseline is a checked-in JSON list; every entry names a finding key,
a repo-relative path, the enclosing symbol, and a mandatory ``why``
justification. Matching is line-number independent so refactors inside
a function don't churn the file. One entry suppresses every finding
with the same (key, path, symbol) — intentional patterns usually
produce a handful of hits in one function.

Workflow:
  * ``python -m repro.analysis src tests`` — exit 0 iff every finding
    is baselined (stale entries warn).
  * ``python -m repro.analysis --write-baseline`` — regenerate entries
    for current findings with ``why: TODO`` placeholders; fill the
    justifications in before committing.
"""
from __future__ import annotations

import json
from pathlib import Path

DEFAULT_BASELINE = "analysis_baseline.json"


class BaselineError(ValueError):
    pass


def load(path: Path) -> list[dict]:
    if not path.exists():
        return []
    entries = json.loads(path.read_text())
    if not isinstance(entries, list):
        raise BaselineError(f"{path}: expected a JSON list")
    for e in entries:
        missing = {"key", "path", "symbol", "why"} - set(e)
        if missing:
            raise BaselineError(f"{path}: entry {e!r} missing {missing}")
        if not str(e["why"]).strip() or e["why"] == "TODO":
            raise BaselineError(
                f"{path}: entry for {e['key']} at {e['path']}:"
                f"{e['symbol']} needs a real justification")
    return entries


def apply(findings: list, entries: list[dict]):
    """Split findings into (active, suppressed) and report stale
    baseline entries that matched nothing."""
    index = {(e["key"], e["path"], e["symbol"]) for e in entries}
    active, suppressed = [], []
    used = set()
    for f in findings:
        if f.baseline_id in index:
            suppressed.append(f)
            used.add(f.baseline_id)
        else:
            active.append(f)
    stale = [e for e in entries
             if (e["key"], e["path"], e["symbol"]) not in used]
    return active, suppressed, stale


def write(path: Path, findings: list) -> None:
    seen = set()
    entries = []
    for f in findings:
        if f.baseline_id in seen:
            continue
        seen.add(f.baseline_id)
        entries.append({"key": f.key, "path": f.path, "symbol": f.symbol,
                        "why": "TODO"})
    path.write_text(json.dumps(entries, indent=2) + "\n")
