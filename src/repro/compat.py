"""Version-compat shims for jax / XLA API and text-format drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check keyword was renamed
``check_rep`` -> ``check_vma`` along the way. Call sites in this repo use
the NEW spelling (``jax.shard_map``-style signature with ``check_vma``);
this shim translates for interpreters that only ship the experimental
variant, so the same code runs on both sides of the move.

``hlo_operand_name`` normalizes XLA's HLO-text operand spelling: newer
XLA prints each operand with its full type
(``dot(f32[64,128]{1,0} %Arg_0.1, ...)``) where older versions printed
bare names (``dot(%Arg_0.1, ...)``). The FLOP/traffic analyzer in
``launch.hlo_analysis`` looks shapes up by operand NAME, so un-normalized
typed operands silently dropped every contracting-dim factor (a 64x128 @
128x32 dot counted as 2·64·32 instead of 2·64·32·128).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "cost_analysis", "hlo_operand_name"]


def hlo_operand_name(operand: str) -> str:
    """Bare computation-local name of an HLO operand reference.

    Accepts both spellings — ``%name`` and ``dtype[dims]{layout} %name``
    — and returns ``name``."""
    if not operand:
        return operand
    return operand.split()[-1].lstrip("%")


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``.

    Newer jax returns one flat ``{counter: value}`` dict; older versions
    return a per-device list of such dicts (single-device compiles: a
    one-element list). Returns the flat dict either way, ``{}`` when the
    backend provides nothing.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; the experimental fallback otherwise.

    Three vintages exist: experimental-only (``check_rep``), top-level
    with ``check_rep`` (the move predates the rename), and top-level with
    ``check_vma`` — hence the TypeError fallback, not just hasattr."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
