"""Version-compat shims for jax API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check keyword was renamed
``check_rep`` -> ``check_vma`` along the way. Call sites in this repo use
the NEW spelling (``jax.shard_map``-style signature with ``check_vma``);
this shim translates for interpreters that only ship the experimental
variant, so the same code runs on both sides of the move.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "cost_analysis"]


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``.

    Newer jax returns one flat ``{counter: value}`` dict; older versions
    return a per-device list of such dicts (single-device compiles: a
    one-element list). Returns the flat dict either way, ``{}`` when the
    backend provides nothing.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; the experimental fallback otherwise.

    Three vintages exist: experimental-only (``check_rep``), top-level
    with ``check_rep`` (the move predates the rename), and top-level with
    ``check_vma`` — hence the TypeError fallback, not just hasattr."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
