"""Typed E / P / D serving stages (paper §3.1).

Each stage class owns its jitted functions and per-stage state and is
unit-testable without threads: every method is synchronous, the engine
merely wires stage instances over ψ channels and drives them from worker
threads. Variants behind one interface:

  EncodeStage        IRP shard planning + jitted encoder (§3.2.2)
  DensePrefillStage  full prefill -> padded per-request cache
  PagedPrefillStage  CHUNKED prefill into pool blocks (ψ_PD = block
                     table; start()/run_chunk() driven by the scheduler)
  DenseDecodeStage   continuous batching over per-request caches
  PagedDecodeStage   ONE jitted batched step over fixed slots / shared pool

Both decode stages thread ``SamplingParams`` into a sampled decode head
(``dense.sample_tokens``): temperature-0 requests stay bit-identical to
the historical argmax path.
"""
from __future__ import annotations

import hashlib
import math
import queue
import threading
import time
from typing import Any, Callable, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.block_manager import KVBlockManager, OutOfBlocks
from repro.kernels.registry import AttentionBackend, resolve_backend
from repro.models import dense
from repro.serving.transfer import (MMTokenCache, PrefillProgress, PsiPD,
                                    ShardStream)
from repro.serving.types import EngineConfig, ServeRequest

PAGED_FAMILIES = ("dense", "moe", "vlm")


class ServeStats:
    """Counters shared across stages (P and D both update peaks)."""

    def __init__(self):
        self.lock = threading.Lock()
        self.data: dict[str, Any] = {
            "decode_tokens": 0, "decode_time": 0.0, "decode_steps": 0,
            "peak_cache_bytes": 0, "preemptions": 0,
            "mm_cache_hits": 0, "mm_cache_misses": 0,
            "prefill_chunks": 0, "admission_backoffs": 0,
            "mm_inflight_hits": 0, "aborts": 0,
            # per-stage job counters (sim cross-validation reads these;
            # both engines bump them) + cluster-only bookkeeping
            # (pd_migrations / role_switches / role_seconds stay 0/empty
            # on single-pipeline engines)
            "encode_shards": 0, "prefill_completions": 0,
            "pd_migrations": 0, "role_switches": 0,
            "monitor_errors": 0, "role_seconds": {},
            # token-packed ModelRunner: executions of THE one packed
            # program, and the number of distinct compiled shapes it has
            # (== len(bucket ladder) once warm; tests assert it stops
            # growing mid-run)
            "packed_steps": 0, "packed_compiles": 0,
            "packed_prefill_tokens": 0,
            # KV prefix caching (EngineConfig.prefix_cache): requests that
            # reused >= 1 cached prompt block / total prompt tokens served
            # from the index instead of prefill compute / LRU evictions /
            # copy-on-write block copies / follower backoffs behind an
            # in-flight identical prefill
            "prefix_cache_hits": 0, "prefix_tokens_reused": 0,
            "prefix_evictions": 0, "cow_copies": 0,
            "prefix_inflight_waits": 0,
            # distinct block-table widths the packed runner has padded to
            # (like packed_compiles: stops growing once warm)
            "packed_table_widths": 0,
            # encode–prefill overlap + packed encode lanes: prefill
            # chunks run before the request's full ψ_EP merge landed /
            # encoder patch-group rows executed inside the packed
            # per-iteration program / highest encoded watermark (prompt
            # tokens) a still-streaming request was prefilled under
            "overlap_chunks_early": 0, "encode_lane_rows": 0,
            "overlap_watermark_hwm": 0,
            # fault tolerance + elastic scaling (supervisor bookkeeping;
            # the simulator's fault_stats uses the same key names so
            # sim-vs-real cross-validation compares directly)
            "instance_deaths": 0, "fault_failovers": 0,
            "fault_replays": 0, "jobs_rerouted": 0,
            "scale_ups": 0, "scale_downs": 0}
        self.live_cache_bytes = 0        # dense-mode KV accounting

    def peak(self, live_bytes: int) -> None:
        with self.lock:
            self.data["peak_cache_bytes"] = max(
                self.data["peak_cache_bytes"], live_bytes)

    def add_live(self, nbytes: int) -> None:
        with self.lock:
            self.live_cache_bytes += nbytes
            self.data["peak_cache_bytes"] = max(
                self.data["peak_cache_bytes"], self.live_cache_bytes)

    def sub_live(self, nbytes: int) -> None:
        with self.lock:
            self.live_cache_bytes -= nbytes

    def bump(self, key: str, n: int = 1) -> None:
        with self.lock:
            self.data[key] += n

    def set_hwm(self, key: str, value: int) -> None:
        """Record a high-water mark (e.g. distinct packed table widths)."""
        with self.lock:
            self.data[key] = max(self.data[key], value)

    def add_role_time(self, role: str, seconds: float) -> None:
        """Accumulate per-role occupancy (cluster role-switch accounting)."""
        with self.lock:
            occ = self.data["role_seconds"]
            occ[role] = occ.get(role, 0.0) + seconds


def cache_nbytes(cache) -> int:
    return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(cache)))


# one shared jitted sampler: every stage samples through the same
# compilation cache (the fn is identical everywhere)
_sample_jit = jax.jit(dense.sample_tokens)


def _sample_one(logits, req: ServeRequest) -> int:
    """Sample the next token for a single request (B=1 jitted sampler).

    The fold position is ``len(req.tokens)`` — the index of the token
    being generated — identical across dense/paged paths and across
    preemption replays."""
    s = req.sampling
    if s.greedy:
        # host argmax, no extra jitted dispatch: keeps the per-request
        # dense baseline's per-token cost identical to the pre-sampling
        # engine (sample_tokens' greedy branch is bit-identical to this)
        return int(np.argmax(np.asarray(logits[0])))
    tok = _sample_jit(logits,
                      jnp.asarray([s.temperature], jnp.float32),
                      jnp.asarray([s.top_p], jnp.float32),
                      jnp.asarray([s.seed], jnp.uint32),
                      jnp.asarray([len(req.tokens)], jnp.int32))
    return int(np.asarray(tok)[0])


# ===================================================================== E
class EncodeStage:
    """E: IRP patch-group sharding + the jitted multimodal encoder."""

    def __init__(self, model, cfg: ArchConfig, params, n_workers: int, *,
                 kit: Optional["PagedJitKit"] = None,
                 stats: Optional[ServeStats] = None):
        self.cfg = cfg
        self.params = params
        self.n_workers = max(1, n_workers)
        if kit is not None:
            self.encode_fn = kit.encode_fn
        else:
            self.encode_fn = jax.jit(model.encode) if model.encode else None
        self.stats = stats
        self.shards_run = 0              # total shard forwards executed
        self._lock = threading.Lock()

    def plan_shards(self, req: ServeRequest) -> list[np.ndarray]:
        """Intra-Request Parallelism: split the PATCH GROUPS across E
        workers. Boundaries align to tokens_per_item so each shard is a
        whole number of independently-encoded patches (lossless merge,
        paper §3.2.2). Returns per-shard index arrays into mm_embeds."""
        M = req.mm_embeds.shape[0]
        tpi = self.cfg.modality.tokens_per_item if self.cfg.modality else M
        n_groups = -(-M // tpi)
        n = max(1, min(self.n_workers, n_groups))
        group_ids = np.array_split(np.arange(n_groups), n)
        return [np.concatenate([np.arange(g * tpi, min((g + 1) * tpi, M))
                                for g in gids]) for gids in group_ids]

    def encode_shard(self, req: ServeRequest, idx: np.ndarray) -> np.ndarray:
        """Encode one shard of a request's modality payload -> tokens."""
        shard = jnp.asarray(req.mm_embeds[idx])[None]           # (1, m, d)
        tokens = np.asarray(self.encode_fn(self.params, shard)[0])
        with self._lock:
            self.shards_run += 1
        if self.stats is not None:
            self.stats.bump("encode_shards")
        return tokens

    def note_shards(self, n: int = 1) -> None:
        """Account shard forwards executed elsewhere (packed encode
        lanes run the forward inside the runner's program; the shard
        plan — and therefore these counters — is identical either way)."""
        with self._lock:
            self.shards_run += n
        if self.stats is not None:
            self.stats.bump("encode_shards", n)


# ===================================================================== P
class PrefillStage(Protocol):
    def prefill(self, req: ServeRequest,
                mm_tokens: Optional[np.ndarray]):
        """Run the whole prefill, emit the first token, return the ψ_PD
        handoff (a tuple in dense mode, a completed ``PrefillProgress``
        in paged mode) — or None if admission must back off (pool full).
        The paged stage additionally exposes ``start``/``run_chunk`` so
        the scheduler can interleave decode steps between chunks."""


def _prefill_premerged(cfg: ArchConfig, params, batch, max_len,
                       backend: Optional[AttentionBackend] = None):
    """Prefill that takes ALREADY-ENCODED mm tokens (EPD path: E ran
    elsewhere), materializing a padded dense cache."""
    B, S = batch["tokens"].shape
    logits, ks, vs = dense.prefill_core(params, cfg, batch, backend=backend)
    if max_len > S:
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


class DensePrefillStage:
    """P (dense): full prefill into a padded per-request cache.

    Works for every model family (the jitted fn wraps ``model.prefill``);
    ψ_PD moves the whole cache to the decode stage. For the paged-capable
    families the attention routes through ``backend`` (the ``ref``
    backend is the substrate itself, so the default is bit-identical to
    the historical path); other families keep their own attention."""

    def __init__(self, model, cfg: ArchConfig, params,
                 ecfg: EngineConfig, stats: ServeStats, *,
                 backend: Optional[AttentionBackend] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.stats = stats
        routed = backend is not None and cfg.family in PAGED_FAMILIES
        # prefill variants retrace per (S, max_len) pair
        if routed:
            self._prefill = jax.jit(
                lambda p, b, ml: dense.prefill(p, cfg, b, max_len=ml,
                                               backend=backend),
                static_argnums=(2,))
        else:
            self._prefill = jax.jit(
                lambda p, b, ml: model.prefill(p, batch=b, max_len=ml),
                static_argnums=(2,))
        self._prefill_merged = jax.jit(
            lambda p, b, ml: _prefill_premerged(cfg, p, b, ml,
                                                backend if routed else None),
            static_argnums=(2,))

    def prefill(self, req: ServeRequest,
                mm_tokens: Optional[np.ndarray]) -> tuple:
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        if self.cfg.family == "audio":
            batch["enc_frames"] = jnp.asarray(req.mm_embeds)[None]
        S = int(batch["tokens"].shape[1])
        max_len = S + req.max_new_tokens + self.ecfg.cache_headroom
        if mm_tokens is not None:
            # tokens already encoded at E; hand P the merged mm tokens
            b = dict(batch)
            b["mm_tokens"] = jnp.asarray(mm_tokens)[None]
            b["mm_positions"] = jnp.asarray(req.mm_positions)[None]
            logits, cache = self._prefill_merged(self.params, b, max_len)
        else:
            logits, cache = self._prefill(self.params, batch, max_len)
        tok = _sample_one(logits, req)
        req.accept(tok)      # stop-at-first-token retires at D admission
        req.t_first_token = time.perf_counter()
        # live-KV accounting: a dense cache exists from prefill to
        # completion (it pads every request to S + max_new + headroom)
        self.stats.add_live(cache_nbytes(cache))
        return (req, tok, cache)


def prefix_salt(req: ServeRequest) -> str:
    """Request-invariant context folded into the prefix-cache chain root:
    multimodal prompts with byte-identical token ids but different images
    (or different mm placements) must never share KV blocks, so the mm
    content hash + positions salt the chain — this is also what lets a
    ψ_EP mm-cache hit compose with a KV prefix hit."""
    if req.mm_embeds is None:
        return ""
    pos = np.ascontiguousarray(np.asarray(req.mm_positions, np.int32))
    return (MMTokenCache.content_key(req.mm_embeds)
            + hashlib.sha1(pos.tobytes()).hexdigest())


def _bucket_ladder(quantum: int, cap: int) -> tuple[int, ...]:
    """Static widths for shape-bucketed jit calls: quantum-doubling up
    to ``cap``. Shared by the packed runner's prefill-region/block-table
    ladders and the migration scatter below."""
    cap = max(quantum, -(-cap // quantum) * quantum)
    widths = []
    w = quantum
    while w < cap:
        widths.append(w)
        w *= 2
    widths.append(cap)
    return tuple(widths)


class PagedKVState:
    """Shared paged KV pool + block manager (P writes, D reads/appends)."""

    def __init__(self, model, cfg: ArchConfig, ecfg: EngineConfig, *,
                 kit: Optional["PagedJitKit"] = None,
                 stats: Optional[ServeStats] = None):
        bs = ecfg.kv_block_size
        on_stat = stats.bump if stats is not None else None
        self.mgr = KVBlockManager(ecfg.kv_blocks, bs,
                                  prefix_cache=ecfg.prefix_cache,
                                  on_stat=on_stat)
        self.lock = threading.Lock()         # guards mgr
        self.pool_lock = threading.Lock()    # guards the pool arrays
        self.max_blocks = math.ceil(ecfg.max_seq_len / bs)
        self.trash = ecfg.kv_blocks          # reserved block id N-1
        self.k_pool, self.v_pool = model.init_kv_pool(ecfg.kv_blocks, bs)
        # migration scatter: jitted + pool-donating via the shared kit
        # (on accelerators donation updates the pool in place instead of
        # copying it per migration) — eager fallback for standalone use.
        # Migrated block counts are padded to a power-of-two ladder so
        # one compile per BUCKET serves every migration size (pad rows
        # scatter zeros into the trash block).
        self._inject_fn = kit.pool_inject if kit is not None else None
        self._copy_fn = kit.pool_copy if kit is not None else None
        self._inject_buckets = _bucket_ladder(1, self.max_blocks)
        # bytes of one (k + v) block pair, for peak-memory accounting
        self.block_bytes = 2 * (cfg.n_layers * bs * cfg.n_kv_heads
                                * cfg.head_dim
                                * self.k_pool.dtype.itemsize)

    # ------------------------------------------------------- copy-on-write
    def ensure_private(self, req_id: int, idx: int) -> None:
        """Make logical block ``idx`` of a request's table private before
        a write lands in it: if the block is shared (refcount > 1), swap
        in a fresh block and copy the pool data. Raises ``OutOfBlocks``
        when no block can be taken for the copy."""
        with self.lock:
            res = self.mgr.cow(req_id, idx)
        if res is None:
            return
        src, dst = res
        with self.pool_lock:
            if self._copy_fn is not None:
                self.k_pool, self.v_pool = self._copy_fn(
                    self.k_pool, self.v_pool,
                    jnp.int32(src), jnp.int32(dst))
            else:
                self.k_pool = self.k_pool.at[:, dst].set(self.k_pool[:, src])
                self.v_pool = self.v_pool.at[:, dst].set(self.v_pool[:, src])

    # -------------------------------------------------- PD cache migration
    def extract(self, req_id: int) -> tuple[np.ndarray, np.ndarray]:
        """Copy a request's KV blocks out of this pool and free them — the
        source half of a cross-instance ψ_PD migration (the paper's PD
        cache transfer). Returns (k, v) of shape (L, nb, bs, K, hd); the
        byte-exact copy keeps migrated decode bit-identical to local."""
        with self.lock:
            blocks = self.mgr.owner_blocks(req_id)
        ids = jnp.asarray(blocks, jnp.int32)
        with self.pool_lock:
            k = np.asarray(self.k_pool[:, ids])
            v = np.asarray(self.v_pool[:, ids])
        with self.lock:
            self.mgr.free(req_id)
        return k, v

    def inject(self, req_id: int, k_blocks: np.ndarray,
               v_blocks: np.ndarray, n_tokens: int,
               keys: Optional[list] = None) -> Optional[int]:
        """Allocate blocks and scatter migrated KV into this pool — the
        destination half of a ψ_PD migration. Returns None (allocating
        nothing) when the pool cannot hold the sequence right now; the
        caller backs off until decode retirements free blocks. ``+1``
        headroom mirrors prefill admission (the first decode write never
        needs an append).

        With ``keys`` (prefix caching), the migrated request RE-PINS any
        prefix already cached on this instance — those blocks are shared,
        only the unmatched suffix is scattered from the migrated copy —
        and its full prompt blocks are committed to the local index so
        later arrivals here hit them. Returns the number of prompt tokens
        re-pinned (0 when nothing matched); block bytes are interchangeable
        across pools because every instance runs the same shared-kit
        executables."""
        use_cache = keys is not None and self.mgr.prefix_cache
        with self.lock:
            if use_cache:
                res = self.mgr.allocate_prefix(req_id, keys, n_tokens + 1)
                if res is None:
                    return None
                blocks, matched = res
            else:
                if not self.mgr.can_allocate(n_tokens + 1):
                    return None
                blocks = self.mgr.allocate(req_id, n_tokens + 1)
                matched = 0
        n_copy = k_blocks.shape[1]
        if matched < n_copy:
            # bucket-pad the scatter so pool_inject compiles once per
            # ladder width, not per migrated block count: pad indices
            # point at the reserved trash block, pad payload is zeros,
            # so real blocks land byte-identically to the unpadded form
            pad = next(w for w in self._inject_buckets
                       if n_copy - matched <= w) - (n_copy - matched)
            ids_np = np.asarray(blocks[matched:n_copy], np.int32)
            kb = np.asarray(k_blocks[:, matched:])
            vb = np.asarray(v_blocks[:, matched:])
            if pad:
                ids_np = np.concatenate(
                    [ids_np, np.full(pad, self.trash, np.int32)])
                zeros = np.zeros((kb.shape[0], pad) + kb.shape[2:],
                                 kb.dtype)
                kb = np.concatenate([kb, zeros], axis=1)
                vb = np.concatenate([vb, zeros], axis=1)
            ids = jnp.asarray(ids_np)
            k = jnp.asarray(kb, self.k_pool.dtype)
            v = jnp.asarray(vb, self.v_pool.dtype)
            with self.pool_lock:
                if self._inject_fn is not None:
                    self.k_pool, self.v_pool = self._inject_fn(
                        self.k_pool, self.v_pool, k, v, ids)
                else:
                    self.k_pool = self.k_pool.at[:, ids].set(k)
                    self.v_pool = self.v_pool.at[:, ids].set(v)
        if use_cache:
            with self.lock:
                self.mgr.commit(req_id, keys)
        return matched * self.mgr.block_size


def _prefill_chunk_step(cfg: ArchConfig, params, k_pool, v_pool, batch,
                        backend: Optional[AttentionBackend] = None):
    """One jitted chunk: gather the prefix KV from the pool through the
    (fixed-width, trash-padded) block table, run the position-offset chunk
    forward, scatter the chunk's KV into its pool blocks. Fixed shapes
    everywhere — one trace serves every chunk of every request."""
    table = batch["table"]                          # (max_blocks,) int32
    bs = k_pool.shape[2]
    L, _, _, K, hd = k_pool.shape
    nb = table.shape[0]
    k_prev = k_pool[:, table].reshape(L, 1, nb * bs, K, hd)
    v_prev = v_pool[:, table].reshape(L, 1, nb * bs, K, hd)
    logits, ks, vs = dense.prefill_chunk_core(params, cfg, {
        "x": batch["x"], "positions": batch["positions"],
        "k_prev": k_prev, "v_prev": v_prev,
        "prev_len": batch["prev_len"], "last_idx": batch["last_idx"]},
        backend=backend)
    k_pool, v_pool = dense.pool_write_prefill(k_pool, v_pool, ks, vs,
                                              batch["chunk_blocks"])
    return logits, k_pool, v_pool


class PagedPrefillStage:
    """P (paged): chunked prefill straight into shared pool blocks.

    ``start`` admits a request (allocates its blocks, embeds the prompt);
    ``run_chunk`` advances it one ``prefill_chunk``-token chunk per call,
    so the scheduler can interleave decode steps between chunks of a long
    prompt. Prompts that fit in one chunk (and the ``prefill_chunk=0``
    baseline) take the original whole-prompt path — bit-identical to the
    unchunked engine. ψ_PD stays a block-table handoff (PrefillProgress)."""

    def __init__(self, model, cfg: ArchConfig, params,
                 ecfg: EngineConfig, stats: ServeStats, kv: PagedKVState, *,
                 kit: Optional["PagedJitKit"] = None):
        self.cfg = cfg
        self.params = params
        self.stats = stats
        self.kv = kv
        bs = ecfg.kv_block_size
        # chunks are block-aligned so each chunk's pool write is whole
        # blocks (the final partial chunk pads into its own allocation)
        self.chunk = (-(-ecfg.prefill_chunk // bs) * bs
                      if ecfg.prefill_chunk > 0 else 0)
        self.prefix_enabled = ecfg.prefix_cache
        self.runner_name = ecfg.runner
        # the jitted programs live in a PagedJitKit so a multi-instance
        # cluster compiles each graph ONCE and every instance (including
        # ones created by a role switch) reuses the same executables
        kit = kit or PagedJitKit(model, cfg)
        self._prefill_core = kit.prefill_core
        self._pool_write = kit.pool_write
        self._chunk_step = kit.chunk_step

    # ------------------------------------------------------------ admission
    def start(self, req: ServeRequest, mm_tokens: Optional[np.ndarray]
              ) -> Optional[PrefillProgress]:
        """Admit a request: allocate its pool blocks and embed the prompt.

        Returns None (without allocating) when the pool cannot hold the
        prompt right now — the scheduler keeps the request at the head of
        its FIFO admission queue (pool-pressure backoff).

        With encode–prefill overlap, ``mm_tokens`` may be a live
        :class:`ShardStream` whose shards are still encoding: the request
        is admitted immediately, already-published shard tokens are
        scattered into the embedded prompt, and the scheduler advances
        its chunk frontier up to the encoded watermark (``sync_stream`` /
        ``span_blocked`` on the returned task)."""
        stream: Optional[ShardStream] = None
        if isinstance(mm_tokens, ShardStream):
            stream = mm_tokens
            mm_tokens = stream.merged      # None while shards are in flight
        S = len(req.prompt)
        keys: Optional[list] = None
        n_cached = 0
        if not self.prefix_enabled:
            with self.kv.lock:
                # +1 headroom so the first decode write never needs append
                if not self.kv.mgr.can_allocate(S + 1):
                    return None
                self.kv.mgr.allocate(req.req_id, S + 1)
                self.stats.peak(self.kv.mgr.used_blocks
                                * self.kv.block_bytes)
        else:
            mgr = self.kv.mgr
            keys = mgr.chain_keys(req.prompt, prefix_salt(req))
            # runner-dependent match cap: the packed runner's prefill rows
            # are per-token independent, so any full-block prefix can be
            # skipped bit-identically; the two_program oracle's chunked
            # prefill is NOT split-invariant, so matches must align to
            # chunk boundaries and leave >= 1 uncached token (and with
            # chunking off, any skip would change the whole-prompt call)
            bs = mgr.block_size
            if self.runner_name == "packed":
                max_match, align = len(keys), 1
            elif self.chunk > 0:
                align = self.chunk // bs
                max_match = ((S - 1) // self.chunk) * align
            else:
                max_match, align = 0, 1
            with self.kv.lock:
                # convergence guard: a preemption replay must wait for
                # FULL uncached headroom before re-admitting (it still
                # reuses cached blocks once admitted). Shared-prefix
                # admission is otherwise so cheap that replays re-enter
                # immediately, over-commit the pool, and starve decode
                # growth forever (a 3-way preempt/replay livelock the
                # uncached path never had).
                if req.n_preemptions > 0 and not mgr.can_allocate(S + 1):
                    return None
                n_hit = min(mgr.match_len(keys), max_match)
                if n_hit < len(keys):
                    # follower-dedup: the next block we'd prefill is being
                    # produced by an in-flight identical prefill — back
                    # off (FIFO head) until the leader commits, instead of
                    # recomputing it. The leader is always the scheduler's
                    # active task or already complete, so no deadlock.
                    holder = mgr.inflight_holder(keys[n_hit])
                    if holder is not None and holder != req.req_id:
                        self.stats.bump("prefix_inflight_waits")
                        return None
                res = mgr.allocate_prefix(req.req_id, keys, S + 1,
                                          max_match_blocks=max_match,
                                          align_blocks=align)
                if res is None:
                    return None
                _, matched = res
                mgr.register_inflight(req.req_id, keys[matched:])
                self.stats.peak(mgr.used_blocks * self.kv.block_bytes)
            n_cached = matched * bs
            if n_cached:
                self.stats.bump("prefix_cache_hits")
                self.stats.bump("prefix_tokens_reused", n_cached)
        toks = jnp.asarray(req.prompt)[None]
        mm_t = (jnp.asarray(mm_tokens)[None]
                if mm_tokens is not None else None)
        mm_p = (jnp.asarray(req.mm_positions)[None]
                if mm_tokens is not None else None)
        # eager embed (a gather + scatter): chunks then slice the embedded
        # prompt on the host, so mm-token merging never retraces per chunk
        x = np.asarray(dense.embed_inputs(self.params, self.cfg, toks,
                                          mm_t, mm_p)[0])
        if stream is not None and mm_tokens is None:
            # scatter whatever shards already landed; later publications
            # are pulled in by sync_stream before each chunk. The copy
            # makes x writable (np.asarray of a device buffer is a
            # read-only view) — streaming admissions only.
            x = np.array(x)
            stream.fill(x)
        return PrefillProgress(req=req, x=x, mm_tokens=mm_tokens,
                               n_done=n_cached, keys=keys, stream=stream)

    def abandon(self, task: PrefillProgress) -> None:
        """Release a started task's blocks (failure / shutdown)."""
        with self.kv.lock:
            self.kv.mgr.free(task.req.req_id)

    def commit_cache(self, task: PrefillProgress) -> None:
        """Prefill complete: publish the prompt's full blocks into the
        prefix index (and release the in-flight claim) so later requests
        — and waiting followers — can share them."""
        if not self.prefix_enabled or task.keys is None:
            return
        with self.kv.lock:
            self.kv.mgr.commit(task.req.req_id, task.keys)

    # --------------------------------------------------------------- chunks
    def run_chunk(self, task: PrefillProgress) -> bool:
        """Advance one chunk; True when the prompt is fully prefilled
        (first token sampled + emitted, task ready for ψ_PD)."""
        req = task.req
        S = task.total
        if task.done:
            # fully-cached admission: nothing to prefill — the first
            # token is sampled by the decode stage's pending-x row
            return True
        if self.chunk <= 0 or (task.n_done == 0 and S <= self.chunk):
            return self._run_whole(task)
        t0 = task.n_done
        C = self.chunk
        valid = min(C, S - t0)
        bs = self.kv.mgr.block_size
        xc = np.zeros((1, C) + task.x.shape[1:], task.x.dtype)
        xc[0, :valid] = task.x[t0:t0 + valid]
        with self.kv.lock:
            owned = self.kv.mgr.owner_blocks(req.req_id)
        table = np.full((self.kv.max_blocks,), self.kv.trash, np.int32)
        table[:len(owned)] = owned
        # this chunk's write targets; overflow past the allocation (final
        # chunk padding) lands in the trash block
        cb = np.full((C // bs,), self.kv.trash, np.int32)
        first = t0 // bs
        n_real = min(len(owned) - first, C // bs)
        cb[:n_real] = owned[first:first + n_real]
        batch = {
            "x": jnp.asarray(xc),
            "positions": jnp.arange(t0, t0 + C, dtype=jnp.int32)[None],
            "table": jnp.asarray(table),
            "chunk_blocks": jnp.asarray(cb),
            "prev_len": jnp.int32(t0),
            "last_idx": jnp.int32(valid - 1)}
        with self.kv.pool_lock:
            logits, self.kv.k_pool, self.kv.v_pool = self._chunk_step(
                self.params, self.kv.k_pool, self.kv.v_pool, batch)
        task.n_done += valid
        self.stats.bump("prefill_chunks")
        if not task.done:
            return False
        return self._finish_prefill(task, logits)

    def _run_whole(self, task: PrefillProgress) -> bool:
        """Unchunked path (short prompt, or the prefill_chunk=0 baseline):
        bit-identical to the pre-scheduler whole-prompt prefill."""
        req = task.req
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        if task.mm_tokens is not None:
            batch["mm_tokens"] = jnp.asarray(task.mm_tokens)[None]
            batch["mm_positions"] = jnp.asarray(req.mm_positions)[None]
        with self.kv.lock:
            ids = jnp.asarray(self.kv.mgr.owner_blocks(req.req_id),
                              jnp.int32)
        logits, ks, vs = self._prefill_core(self.params, batch)
        with self.kv.pool_lock:
            self.kv.k_pool, self.kv.v_pool = self._pool_write(
                self.kv.k_pool, self.kv.v_pool, ks, vs, ids)
        task.n_done = task.total
        self.stats.bump("prefill_chunks")
        return self._finish_prefill(task, logits)

    def _finish_prefill(self, task: PrefillProgress, logits) -> bool:
        tok = _sample_one(logits, task.req)
        task.first_tok = tok
        task.req.accept(tok)   # stop-at-first-token retires at D admission
        task.req.t_first_token = time.perf_counter()
        self.stats.bump("prefill_completions")
        return True

    # ------------------------------------------------------------- compat
    def prefill(self, req: ServeRequest,
                mm_tokens: Optional[np.ndarray]) -> Optional[PrefillProgress]:
        """Whole-prompt convenience (standalone/stage tests): start + run
        chunks to completion. None if the pool is full right now."""
        task = self.start(req, mm_tokens)
        if task is None:
            return None
        while not self.run_chunk(task):
            pass
        return task


# ===================================================================== D
class DenseDecodeStage:
    """D (dense): continuous batching over independent (cache, token)
    pairs, one jitted batch-1 call per request per iteration. Kept as the
    comparison baseline for the paged-batched decode stage."""

    def __init__(self, model, cfg: ArchConfig, params, ecfg: EngineConfig,
                 stats: ServeStats, on_finish: Callable[[ServeRequest], None],
                 *, backend: Optional[AttentionBackend] = None):
        self.params = params
        self.ecfg = ecfg
        self.stats = stats
        self.on_finish = on_finish
        if backend is not None and cfg.family in PAGED_FAMILIES:
            self._decode = jax.jit(
                lambda p, b: dense.decode_step(p, cfg, b, backend=backend))
        else:
            self._decode = jax.jit(lambda p, b: model.decode_step(p, batch=b))
        self._active: list[tuple] = []

    def step(self, psi_pd: PsiPD) -> bool:
        """One scheduler iteration; returns False when idle."""
        while len(self._active) < self.ecfg.decode_batch:
            try:
                self._active.append(psi_pd.recv_nowait())
            except queue.Empty:
                break
        if not self._active:
            return False
        t0 = time.perf_counter()
        nxt = []
        stepped = 0
        for req, tok, cache in self._active:
            if req.finished:               # failed externally (shutdown)
                self.stats.sub_live(cache_nbytes(cache))
                continue
            if req.done_generating:        # length budget or stop token
                self.stats.sub_live(cache_nbytes(cache))
                self.on_finish(req)
                continue
            logits, cache = self._decode(
                self.params,
                {"token": jnp.asarray([tok], jnp.int32), "cache": cache})
            tok = _sample_one(logits, req)
            req.accept(tok)                # stop latches; retires next pass
            stepped += 1
            nxt.append((req, tok, cache))
        if stepped:
            with self.stats.lock:
                self.stats.data["decode_time"] += time.perf_counter() - t0
                self.stats.data["decode_tokens"] += stepped
                self.stats.data["decode_steps"] += 1
        self._active = nxt
        return True

    def abort_all(self, on_fail: Callable[[ServeRequest], None]) -> None:
        """Fail every in-flight request (step() raised); releases their
        cache accounting so the stage can keep serving new arrivals."""
        for req, _, cache in self._active:
            self.stats.sub_live(cache_nbytes(cache))
            on_fail(req)
        self._active = []


def _paged_step_sampled(model, params, batch,
                        backend: Optional[AttentionBackend]):
    """Batched paged decode + sampled head in one jitted body."""
    logits, _, ks, vs = model.paged_decode_step(params, batch=batch,
                                                backend=backend)
    nxt = dense.sample_tokens(logits, batch["temperature"], batch["top_p"],
                              batch["seeds"], batch["sample_pos"])
    return logits, nxt, ks, vs


class PagedJitKit:
    """The jitted programs behind the paged E/P/D stages.

    Stage objects hold per-pool *state*; the kit holds the pure compiled
    *functions*. One kit serves every stage instance built from the same
    (model, cfg) — a multi-instance cluster compiles each graph once, and
    a dynamic role switch builds fresh stage objects without recompiling.
    The token-packed ``packed_step`` (ModelRunner) lives here too, so N
    instances and every role swap share its per-bucket executables.

    ``backend`` is an :class:`~repro.kernels.registry.AttentionBackend`
    (or a name for ``resolve_backend``): every attention site inside the
    kit's programs dispatches through it. The default resolution keeps
    the historical behavior — pure-jnp ``ref`` off-TPU, compiled Pallas
    kernels on TPU.

    Pool buffers are donated so XLA updates them in place instead of
    copying the whole pool every step (CPU ignores donation and warns, so
    donation is only enabled on accelerators)."""

    def __init__(self, model, cfg: ArchConfig,
                 backend: Optional[AttentionBackend | str] = None):
        on_cpu = jax.default_backend() == "cpu"
        if backend is None or isinstance(backend, str):
            backend = resolve_backend(backend)
        self.backend = backend
        self.encode_fn = jax.jit(model.encode) if model.encode else None
        self.prefill_core = jax.jit(
            lambda p, b: dense.prefill_core(p, cfg, b, backend=backend))
        self.pool_write = jax.jit(
            dense.pool_write_prefill,
            donate_argnums=() if on_cpu else (0, 1))
        self.chunk_step = jax.jit(
            lambda p, kp, vp, b: _prefill_chunk_step(cfg, p, kp, vp, b,
                                                     backend),
            donate_argnums=() if on_cpu else (1, 2))
        self.decode_step = jax.jit(
            lambda p, b: _paged_step_sampled(model, p, b, backend),
            donate_argnums=() if on_cpu else (1,))
        # THE token-packed program: decode slots + prefill chunks in one
        # forward per scheduler iteration (serving.runner.ModelRunner
        # assembles its flat batch and tracks its compile count)
        self.packed_step = jax.jit(
            lambda p, b: dense.packed_step_core(p, cfg, b, backend=backend),
            donate_argnums=() if on_cpu else (1,))
        # packed ENCODE LANES (EngineConfig.encode_lanes): when an
        # iteration carries both LM rows and encoder patch-group rows,
        # this combined program runs all three stages in ONE dispatch —
        # the encode operand is (G_bucket, tokens_per_item, enc_d), each
        # row one whole patch group, exactly the encoder's per-segment
        # math (encode-only iterations reuse ``encode_fn`` at the same
        # bucketed shape). None for families without a paged encoder.
        if model.encode is not None and cfg.family in PAGED_FAMILIES:
            self.packed_epd_step = jax.jit(
                lambda p, b, ex: (
                    dense.packed_step_core(p, cfg, b, backend=backend),
                    model.encode(p, ex)),
                donate_argnums=() if on_cpu else (1,))
        else:
            self.packed_epd_step = None
        # PD-migration scatter (PagedKVState.inject): block counts are
        # bucket-padded by the caller, so this compiles once per ladder
        # width; donates the destination pool
        self.pool_inject = jax.jit(
            lambda kp, vp, k, v, ids: (kp.at[:, ids].set(k),
                                       vp.at[:, ids].set(v)),
            donate_argnums=() if on_cpu else (0, 1))
        # copy-on-write block copy (PagedKVState.ensure_private): one
        # fixed-shape trace serves every (src, dst) pair
        self.pool_copy = jax.jit(
            lambda kp, vp, src, dst: (kp.at[:, dst].set(kp[:, src]),
                                      vp.at[:, dst].set(vp[:, src])),
            donate_argnums=() if on_cpu else (0, 1))

    def packed_shapes_compiled(self) -> int:
        """Distinct compiled shapes of the packed program(s) — the
        compile counter surfaced as ``ServeStats['packed_compiles']``.
        Includes the combined encode-lane variant so lane buckets are
        under the same zero-mid-run-recompiles bar."""
        n = int(self.packed_step._cache_size())
        if self.packed_epd_step is not None:
            n += int(self.packed_epd_step._cache_size())
        return n


class PagedDecodeStage:
    """D (paged): fixed decode slots over the shared paged pool — admit
    from ψ_PD into free slots, grow allocations via KVBlockManager.append,
    ONE jitted batched step per iteration regardless of the active count
    (inactive slots pad to the trash block, so the call never recompiles
    as requests come and go)."""

    def __init__(self, model, cfg: ArchConfig, params, ecfg: EngineConfig,
                 stats: ServeStats, kv: PagedKVState,
                 on_finish: Callable[[ServeRequest], None],
                 on_requeue: Callable[[ServeRequest, Any], None], *,
                 kit: Optional[PagedJitKit] = None):
        self.params = params
        self.stats = stats
        self.kv = kv
        self.on_finish = on_finish
        self.on_requeue = on_requeue
        n = ecfg.decode_batch
        self._slots: list[Optional[dict]] = [None] * n
        # fully-cached admissions (prefix cache): the embedded last prompt
        # token, pending a one-shot packed prefill row that recomputes the
        # final position's logits to sample the first token
        self._x_pending: list[Optional[np.ndarray]] = [None] * n
        self._tokens = np.zeros((n,), np.int32)
        self._positions = np.zeros((n,), np.int32)
        self._tables = np.full((n, kv.max_blocks), kv.trash, np.int32)
        # per-slot sampling state
        self._temps = np.zeros((n,), np.float32)
        self._top_ps = np.ones((n,), np.float32)
        self._seeds = np.zeros((n,), np.uint32)
        self._gen = np.zeros((n,), np.int32)     # tokens generated so far
        kit = kit or PagedJitKit(model, cfg)
        self._step = kit.decode_step

    # ------------------------------------------------------------- admit
    def _admit(self, psi_pd: PsiPD) -> None:
        for i in range(len(self._slots)):
            if self._slots[i] is not None:
                continue
            try:
                handoff: PrefillProgress = psi_pd.recv_nowait()
            except queue.Empty:
                break
            req = handoff.req
            if req.finished:
                # aborted while parked in ψ_PD: the handoff's block-table
                # reference is the last owner — free here, on the decode
                # stage's own thread
                with self.kv.lock:
                    self.kv.mgr.free(req.req_id)
                continue
            if handoff.first_tok is None:
                # fully-cached prompt: no prefill ran, so no first token
                # yet. The next packed step recomputes the last prompt
                # position from the embedded x (pending-x row) to sample
                # it; that (byte-identical) rewrite lands in the final
                # prompt block, so take a private copy if it's shared.
                bs = self.kv.mgr.block_size
                try:
                    self.kv.ensure_private(req.req_id,
                                           (handoff.total - 1) // bs)
                except OutOfBlocks:
                    with self.kv.lock:
                        self.kv.mgr.free(req.req_id)
                    req.reset_generation()
                    self.stats.bump("preemptions")
                    self.on_requeue(req, handoff.mm_tokens)
                    continue
                self._x_pending[i] = np.asarray(handoff.x_last)
                self._tokens[i] = 0
                self._positions[i] = handoff.total - 1
            else:
                self._x_pending[i] = None
                self._tokens[i] = handoff.first_tok
                self._positions[i] = handoff.total
            with self.kv.lock:
                blocks = self.kv.mgr.owner_blocks(req.req_id)
            self._slots[i] = {"req": req, "mm_tokens": handoff.mm_tokens}
            self._tables[i, :] = self.kv.trash
            self._tables[i, :len(blocks)] = blocks
            self._temps[i] = req.sampling.temperature
            self._top_ps[i] = req.sampling.top_p
            self._seeds[i] = req.sampling.seed
            self._gen[i] = len(req.tokens)

    def _retire(self) -> None:
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            req = s["req"]
            if req.finished:                # failed externally (shutdown)
                with self.kv.lock:
                    self.kv.mgr.free(req.req_id)
                self._slots[i] = None
                self._x_pending[i] = None
                self._tables[i, :] = self.kv.trash
            elif req.done_generating and self._x_pending[i] is None:
                # length budget or stop token (a pending-x slot hasn't
                # sampled its first token yet, so it never retires here)
                with self.kv.lock:
                    self.kv.mgr.free(req.req_id)
                self.on_finish(req)
                self._slots[i] = None
                self._tables[i, :] = self.kv.trash

    def _preempt(self, i: int) -> None:
        """OutOfBlocks under decode pressure: free this slot's blocks and
        requeue the request through P (the deterministic replay — greedy
        or seeded sampling — reproduces the same prefix)."""
        s = self._slots[i]
        req = s["req"]
        self.kv.mgr.free(req.req_id)      # caller holds kv.lock
        req.reset_generation()
        self.stats.bump("preemptions")
        self._slots[i] = None
        self._x_pending[i] = None
        self._tables[i, :] = self.kv.trash
        self.on_requeue(req, s["mm_tokens"])

    def abort_all(self, on_fail: Callable[[ServeRequest], None]) -> None:
        """Fail every slotted request (step() raised); frees their pool
        blocks so the stage can keep serving new arrivals."""
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            with self.kv.lock:
                self.kv.mgr.free(s["req"].req_id)
            on_fail(s["req"])
            self._slots[i] = None
            self._x_pending[i] = None
            self._tables[i, :] = self.kv.trash

    def evacuate(self) -> list[dict]:
        """Export every live slot for failover/retirement WITHOUT freeing
        its pool blocks (the caller migrates or frees per resident).

        Must run on the instance's executor thread, or after that thread
        has exited (dead instance): the slot arrays are executor-private.
        Each entry carries exactly what a ψ_PD re-admission needs:
        ``last_tok``/``position`` mirror a normal handoff's
        (first_tok, total); a pending-x slot (fully-cached admit that has
        not sampled yet) instead exports ``x_pending`` with position+1 KV
        tokens, matching the token-less handoff shape."""
        out: list[dict] = []
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            pending = self._x_pending[i]
            out.append({
                "req": s["req"], "mm_tokens": s["mm_tokens"],
                "last_tok": None if pending is not None
                else int(self._tokens[i]),
                "position": int(self._positions[i]) + (1 if pending is not None
                                                       else 0),
                "x_pending": None if pending is None else np.asarray(pending),
            })
            self._slots[i] = None
            self._x_pending[i] = None
            self._tables[i, :] = self.kv.trash
        return out

    @property
    def active_count(self) -> int:
        """Occupied decode slots (the scheduler's decode token spend)."""
        return sum(s is not None for s in self._slots)

    # -------------------------------------------------------------- step
    def _prepare(self, psi_pd: PsiPD) -> np.ndarray:
        """Admit from ψ_PD, retire finished slots, grow every live slot's
        allocation for this step's KV write (preempting on pool pressure).
        Returns the active-slot mask — the per-iteration plan the packed
        ModelRunner and the historical batched step both execute from."""
        self._admit(psi_pd)
        self._retire()
        active = np.array([s is not None for s in self._slots], dtype=bool)
        if not active.any():
            return active

        # grow allocations for this step's write; preempt on pressure
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            req = s["req"]
            with self.kv.lock:
                try:
                    new = self.kv.mgr.append(req.req_id, 1,
                                             int(self._positions[i]))
                except OutOfBlocks:
                    owned = len(self.kv.mgr.owner_blocks(req.req_id))
                    if self.kv.mgr.used_blocks <= owned:
                        raise   # pool cannot hold even one request
                    self._preempt(i)
                    active[i] = False
                    continue
            if new:
                have = int((self._tables[i] != self.kv.trash).sum())
                self._tables[i, have:have + len(new)] = new

        if active.any():
            with self.kv.lock:
                self.stats.peak(self.kv.mgr.used_blocks * self.kv.block_bytes)
        return active

    def step(self, psi_pd: PsiPD) -> int:
        """One scheduler iteration; returns the number of slots stepped
        (0 = idle, falsy for the engine's idle-sleep check)."""
        active = self._prepare(psi_pd)
        if any(x is not None for x in self._x_pending):
            # fully-cached admissions only arise under the packed runner
            # (the two_program oracle always prefills >= 1 suffix token)
            raise RuntimeError(
                "pending-x slot reached the two_program decode step")
        if not active.any():
            return 0

        # THE decode step: one jitted call for the whole slot batch
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(self._tokens),
                 "positions": jnp.asarray(self._positions),
                 "active": jnp.asarray(active),
                 "block_tables": jnp.asarray(self._tables),
                 "temperature": jnp.asarray(self._temps),
                 "top_p": jnp.asarray(self._top_ps),
                 "seeds": jnp.asarray(self._seeds),
                 "sample_pos": jnp.asarray(self._gen)}
        with self.kv.pool_lock:
            batch["k_pool"] = self.kv.k_pool
            batch["v_pool"] = self.kv.v_pool
            _, nxt_tok, self.kv.k_pool, self.kv.v_pool = self._step(
                self.params, batch)
        nxt = np.asarray(nxt_tok)
        with self.stats.lock:
            self.stats.data["decode_time"] += time.perf_counter() - t0
            self.stats.data["decode_tokens"] += int(active.sum())
            self.stats.data["decode_steps"] += 1

        for i, s in enumerate(self._slots):
            if s is None or not active[i]:
                continue
            s["req"].accept(int(nxt[i]))   # stop tokens latch, not emit;
            self._tokens[i] = nxt[i]       # slot retires next iteration
            self._positions[i] += 1
            self._gen[i] += 1
        return int(active.sum())
