"""Unified token-packed ModelRunner: ONE jitted forward per iteration.

The historical paged hot path ran two jitted program families per
scheduler iteration — the batched decode step, then one chunk program per
prefill chunk — so a busy iteration dispatched 1 + n_chunks XLA
executions and the accelerator idled between them. ``ModelRunner`` folds
the whole iteration into a single token-packed program
(``dense.packed_step_core``): flat ``(T_bucket,)`` token / position /
write-slot arrays where rows 0..N-1 are the fixed decode slots and the
tail rows are this iteration's prefill-chunk tokens (several chunks, of
several requests, of the SAME request — all just rows). ``T`` is padded
to a small static bucket ladder so shapes never drive a recompile
mid-run; ``ServeStats['packed_steps'/'packed_compiles']`` count
executions and distinct compiled shapes.

Numerics: every row reproduces the pre-refactor math bit-for-bit — a
decode row is exactly ``paged_decode_step``'s row, and a chunk row's
scatter-then-paged-attention read sees the same valid KV entries in the
same order as ``prefix_chunk_attention`` (NEG_INF-masked softmax padding
is exact) — so greedy output is bit-identical to the two-program path,
which survives as the ``EngineConfig.runner = "two_program"`` oracle.

The runner IS the paged decode stage (it subclasses
``PagedDecodeStage`` for the slot/admit/retire/preempt machinery and the
``step()`` interface a decode-only cluster instance drives), plus the
chunk-execution half the scheduler plans into it. Compiled programs live
in the shared ``PagedJitKit`` — N cluster instances and every role swap
reuse the same per-bucket executables.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.stages import (PagedDecodeStage, PagedJitKit,
                                  PagedKVState, ServeStats, _bucket_ladder)
from repro.serving.transfer import PrefillProgress, PsiPD
from repro.serving.types import EngineConfig, ServeRequest

__all__ = ["ChunkWork", "EncodeWork", "ModelRunner"]


@dataclass
class ChunkWork:
    """One planned prefill chunk: ``n_new`` prompt tokens of ``task``
    starting at global position ``t0``, writing into the ``blocks``
    snapshot of the request's pool allocation. ``final`` marks the chunk
    that completes the prompt (its last row's sampled token becomes the
    request's first token)."""
    task: PrefillProgress
    t0: int
    n_new: int
    blocks: np.ndarray
    final: bool


@dataclass
class EncodeWork:
    """One planned IRP encode shard (packed encode lanes): the shard's
    patch groups become ``(tokens_per_item,)``-token segment rows in the
    packed iteration. ``groups`` are whole patch groups (the last may be
    ragged — zero-padded to the segment width, matching the legacy
    multi-group reshape's padding exactly). ``legacy`` marks the one
    shape the lane rows can NOT reproduce bit-identically: a shard that
    is a single ragged group alone attends its ``m < tokens_per_item``
    tokens UNPADDED in the per-shard encoder, so it runs through
    ``encode_fn`` instead."""
    req: ServeRequest
    sid: int
    n_shards: int
    idx: np.ndarray
    key: Optional[str]
    groups: list
    legacy: bool

    @property
    def tokens_cost(self) -> int:
        """Budget tokens this shard charges the iteration."""
        return int(len(self.idx))


class ModelRunner(PagedDecodeStage):
    """Token-packed executor over the shared paged pool.

    Scheduler protocol (one iteration):
      1. ``_prepare(psi_pd)`` (inherited) — admit/retire/grow the decode
         slots, returning the active mask;
      2. the scheduler plans ``ChunkWork`` under its token budget (at
         most ``max_prefill_tokens`` per iteration);
      3. ``execute(active, chunks)`` — ONE packed jitted call; commits
         decode-slot tokens, advances chunk tasks, samples first tokens
         of completed prefills, and returns
         ``(slots_stepped, finished_tasks)``.

    ``step(psi_pd)`` (decode-only protocol, e.g. a cluster D instance)
    is prepare + execute with no chunks. ``n_slots=0`` builds a
    prefill-only runner (a cluster P instance): the inherited slot
    machinery degenerates to no-ops and never touches ψ_PD.
    """

    def __init__(self, model, cfg: ArchConfig, params, ecfg: EngineConfig,
                 stats: ServeStats, kv: PagedKVState,
                 on_finish: Callable[[ServeRequest], None],
                 on_requeue: Callable[[ServeRequest, object], None], *,
                 kit: Optional[PagedJitKit] = None,
                 n_slots: Optional[int] = None):
        if n_slots is not None:
            # a prefill-only runner narrows the decode side before the
            # base class sizes its slot arrays
            ecfg = dataclasses.replace(ecfg, decode_batch=n_slots)
        kit = kit or PagedJitKit(model, cfg, backend=ecfg.attn_backend)
        super().__init__(model, cfg, params, ecfg, stats, kv,
                         on_finish=on_finish, on_requeue=on_requeue, kit=kit)
        self.kit = kit
        self.ecfg = ecfg
        self._packed = kit.packed_step
        self._embed_dtype = np.asarray(params["embed"][:1, :1]).dtype
        self.d_model = cfg.d_model
        self.params = params
        bs = ecfg.kv_block_size
        chunk = (-(-ecfg.prefill_chunk // bs) * bs
                 if ecfg.prefill_chunk > 0 else 0)
        n = len(self._slots)
        if chunk > 0:
            # chunked: the scheduler plans at most budget//chunk chunks
            # per iteration (each costs ``chunk`` budget tokens)
            floor = ecfg.decode_batch + chunk
            budget = max(ecfg.step_token_budget or floor, floor)
            quantum, cap = chunk, max(chunk, (budget // chunk) * chunk)
        else:
            # unchunked baseline: a whole prompt (up to max_seq_len
            # tokens) lands in one iteration's prefill region
            quantum, cap = bs, ecfg.max_seq_len
        self.buckets = _bucket_ladder(quantum, cap)
        self.max_prefill_tokens = self.buckets[-1]
        # block-table width ladder: instead of padding every row's table
        # to max_blocks, pad to the smallest power-of-two-ish bucket that
        # covers the batch's live block counts (short sequences gather a
        # fraction of the pool width; NEG_INF-masked softmax keeps any
        # width bit-exact). Distinct widths used feed the
        # ``packed_table_widths`` compile-shape counter.
        self.table_buckets = _bucket_ladder(1, self.kv.max_blocks)
        self.table_widths_used: set[int] = set()
        # packed encode lanes: encoder patch-group rows ride the same
        # iteration; group counts pad to their own small ladder so lane
        # load never drives a recompile. ``on_encoded(work, tokens)`` is
        # wired by the engine (completes the shard over ψ_EP).
        m = cfg.modality
        if m is not None and kit.packed_epd_step is not None:
            self._tpi = int(m.tokens_per_item)
            self.enc_buckets = _bucket_ladder(
                1, max(1, -(-ecfg.max_seq_len // self._tpi)))
            self.max_encode_groups = self.enc_buckets[-1]
        else:
            self._tpi = 0
            self.enc_buckets = ()
            self.max_encode_groups = 0
        self.on_encoded: Optional[Callable] = None

    # ------------------------------------------------------------- planning
    def next_chunk_len(self, task: PrefillProgress) -> int:
        """Token length of ``task``'s next chunk (whole remainder in the
        unchunked baseline)."""
        remaining = task.total - task.n_done
        if self.ecfg.prefill_chunk <= 0:
            return remaining
        bs = self.kv.mgr.block_size
        chunk = -(-self.ecfg.prefill_chunk // bs) * bs
        return min(chunk, remaining)

    def plan_chunk(self, task: PrefillProgress) -> ChunkWork:
        """Advance ``task`` by one chunk ON PAPER: snapshot its block
        allocation and move the prompt cursor; ``execute`` materializes
        the work (a failed packed call fails every planned task)."""
        n_new = self.next_chunk_len(task)
        t0 = task.n_done
        with self.kv.lock:
            blocks = np.asarray(self.kv.mgr.owner_blocks(task.req.req_id),
                                dtype=np.int32)
        task.n_done += n_new
        return ChunkWork(task=task, t0=t0, n_new=n_new, blocks=blocks,
                         final=task.done)

    def plan_encode(self, job: tuple) -> EncodeWork:
        """Turn a ψ_EP shard job ``(req, sid, n_shards, idx, key)`` into
        lane work: split the shard's (contiguous, group-aligned) index
        span back into whole patch groups."""
        req, sid, n_shards, idx, key = job
        idx = np.asarray(idx)
        tpi = self._tpi
        groups = [idx[i:i + tpi] for i in range(0, len(idx), tpi)]
        legacy = len(groups) == 1 and len(groups[0]) < tpi
        return EncodeWork(req=req, sid=sid, n_shards=n_shards, idx=idx,
                          key=key, groups=groups, legacy=legacy)

    def _prefill_bucket(self, n_tokens: int) -> int:
        for w in self.buckets:
            if n_tokens <= w:
                return w
        raise ValueError(
            f"planned {n_tokens} prefill tokens exceeds the bucket cap "
            f"{self.buckets[-1]} (scheduler budget out of sync)")

    # ------------------------------------------------------------ execution
    def execute(self, active: np.ndarray, chunks: list[ChunkWork],
                encodes: tuple | list = ()
                ) -> tuple[int, list[PrefillProgress]]:
        """Run the iteration plan as ONE packed jitted forward.

        Returns ``(decode_slots_stepped, finished_prefill_tasks)`` —
        finished tasks carry their sampled ``first_tok`` and are ready
        for the scheduler's ψ_PD handoff. With ``encodes`` (packed
        encode lanes), the shard forwards ride the same dispatch: the
        combined ``packed_epd_step`` program when LM rows are present,
        the bucketed encoder alone on an encode-only iteration; each
        completed shard is handed to ``on_encoded``."""
        n = len(self._slots)
        n_pref = sum(c.n_new for c in chunks)
        has_lm = bool(active.any()) or n_pref > 0
        if not has_lm and not encodes:
            return 0, []

        # encode-lane operand: one row per whole patch group, padded to
        # the group-count ladder (pad rows are zeros; row outputs are
        # independent, so pads never perturb real rows)
        lane_works = [w for w in encodes if not w.legacy]
        ex = None
        n_groups = 0
        if lane_works:
            n_groups = sum(len(w.groups) for w in lane_works)
            G = next(g for g in self.enc_buckets if n_groups <= g)
            ref = lane_works[0].req.mm_embeds
            ex = np.zeros((G, self._tpi, ref.shape[-1]), ref.dtype)
            r = 0
            for w in lane_works:
                for g in w.groups:
                    ex[r, :len(g)] = w.req.mm_embeds[g]
                    r += 1

        if not has_lm:
            # encode-only iteration: the lane rows still run as one
            # bucketed program (same math as a combined iteration's
            # encode operand — the rows are batch-independent)
            enc_out = (np.asarray(self.kit.encode_fn(self.params,
                                                     jnp.asarray(ex)))
                       if ex is not None else None)
            with self.stats.lock:
                self.stats.data["packed_steps"] += 1
                self.stats.data["encode_lane_rows"] += n_groups
                self.stats.data["packed_compiles"] = max(
                    self.stats.data["packed_compiles"],
                    self.kit.packed_shapes_compiled())
            self._commit_encodes(encodes, enc_out)
            return 0, []
        T = n + (self._prefill_bucket(n_pref) if n_pref else 0)
        bs = self.kv.mgr.block_size
        trash = self.kv.trash

        # bucket the table width over this batch's live block counts
        # (decode rows are prefix-packed real ids then trash; inactive
        # rows are all trash, so the max covers every gathered entry)
        need = 1
        if n:
            need = max(need, int((self._tables != trash).sum(axis=1).max()))
        for c in chunks:
            need = max(need, len(c.blocks))
        W = next(w for w in self.table_buckets if need <= w)
        self.table_widths_used.add(W)
        self.stats.set_hwm("packed_table_widths", len(self.table_widths_used))

        tok = np.zeros((T,), np.int32)
        pos = np.zeros((T,), np.int32)
        wb = np.full((T,), trash, np.int32)
        ws = np.zeros((T,), np.int32)
        tables = np.full((T, W), trash, np.int32)
        lengths = np.ones((T,), np.int32)
        is_pref = np.zeros((T,), bool)
        x_pref = np.zeros((T, self.d_model), self._embed_dtype)
        temps = np.zeros((T,), np.float32)
        top_ps = np.ones((T,), np.float32)
        seeds = np.zeros((T,), np.uint32)
        sample_pos = np.zeros((T,), np.int32)

        # decode rows 0..n-1: exactly the batched step's per-slot state
        if n:
            tok[:n] = self._tokens
            tables[:n] = self._tables[:, :W]
            temps[:n] = self._temps
            top_ps[:n] = self._top_ps
            seeds[:n] = self._seeds
            sample_pos[:n] = self._gen
            act = np.nonzero(active)[0]
            pos[act] = self._positions[act]
            wb[act] = self._tables[act, self._positions[act] // bs]
            ws[act] = self._positions[act] % bs
            lengths[act] = self._positions[act] + 1
            # pending-x slots (fully-cached admission): a one-shot prefill
            # row that recomputes the final prompt position from the
            # embedded last token — its sampled token is the first token
            for i in act:
                xp = self._x_pending[i]
                if xp is not None:
                    is_pref[i] = True
                    x_pref[i] = xp

        # chunk rows: flat-packed prompt tokens, contiguous per chunk
        lane = n
        finals: list[tuple[int, ChunkWork]] = []   # (last row, work)
        for c in chunks:
            req = c.task.req
            p = np.arange(c.t0, c.t0 + c.n_new)
            rows = slice(lane, lane + c.n_new)
            pos[rows] = p
            wb[rows] = c.blocks[p // bs]
            ws[rows] = p % bs
            tables[rows, :len(c.blocks)] = c.blocks
            lengths[rows] = p + 1
            is_pref[rows] = True
            x_pref[rows] = c.task.x[c.t0:c.t0 + c.n_new]
            if c.final:
                s = req.sampling
                last = lane + c.n_new - 1
                temps[last] = s.temperature
                top_ps[last] = s.top_p
                seeds[last] = s.seed
                sample_pos[last] = len(req.tokens)
                finals.append((last, c))
            lane += c.n_new

        batch = {
            "token_ids": jnp.asarray(tok),
            "x_prefill": jnp.asarray(x_pref),
            "is_prefill": jnp.asarray(is_pref),
            "positions": jnp.asarray(pos),
            "write_block": jnp.asarray(wb),
            "write_slot": jnp.asarray(ws),
            "tables": jnp.asarray(tables),
            "lengths": jnp.asarray(lengths),
            "temperature": jnp.asarray(temps),
            "top_p": jnp.asarray(top_ps),
            "seeds": jnp.asarray(seeds),
            "sample_pos": jnp.asarray(sample_pos),
        }
        t0 = time.perf_counter()
        enc_out = None
        with self.kv.pool_lock:
            batch["k_pool"] = self.kv.k_pool
            batch["v_pool"] = self.kv.v_pool
            if ex is not None:
                # ONE program across all three stages: decode slots,
                # prefill-chunk rows, and encoder patch-group rows
                (_, nxt_tok, self.kv.k_pool, self.kv.v_pool), enc_out_j = \
                    self.kit.packed_epd_step(self.params, batch,
                                             jnp.asarray(ex))
            else:
                _, nxt_tok, self.kv.k_pool, self.kv.v_pool = self._packed(
                    self.params, batch)
        nxt = np.asarray(nxt_tok)
        if ex is not None:
            enc_out = np.asarray(enc_out_j)
        dt = time.perf_counter() - t0

        stepped = int(active.sum())
        with self.stats.lock:
            self.stats.data["packed_steps"] += 1
            self.stats.data["packed_prefill_tokens"] += n_pref
            self.stats.data["encode_lane_rows"] += n_groups
            self.stats.data["packed_compiles"] = max(
                self.stats.data["packed_compiles"],
                self.kit.packed_shapes_compiled())
            if stepped:
                self.stats.data["decode_time"] += dt
                self.stats.data["decode_tokens"] += stepped
                self.stats.data["decode_steps"] += 1

        # commit decode rows (identical to the historical step tail)
        for i, s in enumerate(self._slots):
            if s is None or not active[i]:
                continue
            if self._x_pending[i] is not None:
                # the pending-x row just sampled the request's FIRST token
                self._x_pending[i] = None
                s["req"].t_first_token = time.perf_counter()
            s["req"].accept(int(nxt[i]))   # stop tokens latch, not emit;
            self._tokens[i] = nxt[i]       # slot retires next iteration
            self._positions[i] += 1
            self._gen[i] += 1

        # commit chunk rows: counters + first-token sampling on finals
        finished = []
        for c in chunks:
            self.stats.bump("prefill_chunks")
        for last, c in finals:
            first = int(nxt[last])
            c.task.first_tok = first
            c.task.req.accept(first)   # stop-at-first-token retires at D
            c.task.req.t_first_token = time.perf_counter()
            self.stats.bump("prefill_completions")
            finished.append(c.task)
        if encodes:
            self._commit_encodes(encodes, enc_out)
        return stepped, finished

    def _commit_encodes(self, encodes, enc_out) -> None:
        """Reassemble lane rows into per-shard token arrays and hand
        each to ``on_encoded`` — the engine completes the shard over
        ψ_EP exactly like a threaded E worker would."""
        r = 0
        for w in encodes:
            if w.legacy:
                continue
            parts = []
            for g in w.groups:
                parts.append(enc_out[r, :len(g)])
                r += 1
            self.on_encoded(w, np.concatenate(parts, axis=0))
        for w in encodes:
            if not w.legacy:
                continue
            # a shard that is a single ragged group ALONE attends its
            # m < tokens_per_item tokens UNPADDED in the per-shard
            # encoder (a zero-padded lane row attends the pads too), so
            # bit parity requires the per-shape program here
            tokens = np.asarray(self.kit.encode_fn(
                self.params, jnp.asarray(w.req.mm_embeds[w.idx])[None])[0])
            self.on_encoded(w, tokens)

    # -------------------------------------------------- decode-only protocol
    def step(self, psi_pd: PsiPD) -> int:
        """Decode-only iteration (cluster D instance): prepare the slots
        and run the packed program with an empty prefill region."""
        active = self._prepare(psi_pd)
        stepped, _ = self.execute(active, [])
        return stepped
