"""ψ transfer channels between stages (paper §3.1–§3.2.2).

``PsiEP`` is the E→P handoff: it assembles IRP shard outputs into the
merged multimodal-token tensor (align/merge, §3.2.2) and owns the
content-hash-keyed ``MMTokenCache`` (§3.2.1) so a repeated image/audio
payload skips the E stage entirely — the cached merged tokens are
delivered straight to P.

``PsiPD`` is the P→D handoff: in paged mode it carries a block-table
reference (no KV copy), in dense mode it moves the materialized cache.
On real hardware these channels would be device-to-device puts; here they
are typed thread-safe queues with transfer accounting.
"""
from __future__ import annotations

import hashlib
import queue
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np


def drain_queue(q: queue.Queue) -> list:
    """Empty a queue without blocking (shutdown drains)."""
    out = []
    while True:
        try:
            out.append(q.get_nowait())
        except queue.Empty:
            return out


@dataclass
class PrefillProgress:
    """ψ_PD payload: a request's (possibly partial) prefill state.

    Chunked prefill writes prompt KV into pool blocks chunk-by-chunk;
    this object carries the request between chunks (``n_done`` tokens
    already cached) and, once complete (``done``), travels over ψ_PD to
    the decode stage — the KV never moves, only this reference does.
    ``x`` is the pre-embedded prompt (mm tokens merged at embed time) so
    each chunk is a plain slice; ``mm_tokens`` rides along for the
    preemption requeue path. With prefix caching, ``keys`` carries the
    prompt's hash-chained block keys (committed to the index when the
    prefill completes) and ``n_done`` may start > 0 (cached prefix); a
    FULLY cached prompt arrives at decode with ``first_tok is None`` —
    the first decode step recomputes the last prompt position from
    ``x_last`` to sample it."""
    req: Any
    x: np.ndarray                        # (S, d) embedded prompt inputs
    mm_tokens: Optional[np.ndarray]
    n_done: int = 0                      # prompt tokens already in the pool
    first_tok: Optional[int] = None      # sampled on the final chunk
    keys: Optional[list] = None          # prefix-cache block keys

    @property
    def x_last(self) -> np.ndarray:
        return self.x[-1]

    @property
    def total(self) -> int:
        return self.x.shape[0]

    @property
    def done(self) -> bool:
        return self.n_done >= self.total


@dataclass
class MigratedPrefill:
    """ψ_PD payload BETWEEN instances: the prompt KV copied out of the
    prefill worker's pool (the paper's PD cache migration), waiting to be
    injected into a decode worker's pool. Once injected, the decode stage
    admits it exactly like a local ``PrefillProgress`` (same ``req`` /
    ``first_tok`` / ``total`` / ``mm_tokens`` surface); ``k_blocks`` /
    ``v_blocks`` are dropped after injection to release the copy."""
    req: Any
    first_tok: Optional[int]
    total: int                           # prompt tokens already prefetched
    mm_tokens: Optional[np.ndarray]
    k_blocks: Optional[np.ndarray]       # (L, nb, bs, K, hd)
    v_blocks: Optional[np.ndarray]
    keys: Optional[list] = None          # prefix-cache block keys (re-pin)
    x_last: Optional[np.ndarray] = None  # embedded last prompt token
    #                                      (fully-cached handoff only)


class MMTokenCache:
    """Content-hash-keyed LRU cache of merged multimodal tokens.

    Paper §3.2.1: "cache multimedia tokens for efficient transfer" — the
    key is a digest of the raw modality payload, so identical images or
    audio clips (byte-identical embeddings) across requests reuse the
    encoded tokens and the E stage runs zero shards."""

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self._entries: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def content_key(mm_embeds: np.ndarray) -> str:
        a = np.ascontiguousarray(mm_embeds)
        h = hashlib.sha1(a.tobytes())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        return h.hexdigest()

    def get(self, key: str) -> Optional[np.ndarray]:
        with self._lock:
            tokens = self._entries.get(key)
            if tokens is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return tokens

    def put(self, key: str, tokens: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._entries[key] = tokens
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)


class PsiEP:
    """ψ_EP: multimodal-token handoff from E workers to the P stage."""

    def __init__(self, cache: MMTokenCache):
        self.cache = cache
        self._q: queue.Queue = queue.Queue()
        self._shards: dict[int, list] = {}
        self._lock = threading.Lock()
        self.transfers = 0

    def send(self, req: Any, mm_tokens: Optional[np.ndarray]) -> None:
        """Deliver a prefill-ready request (merged tokens, a cache hit,
        a text-only request, or a preemption requeue)."""
        self.transfers += 1
        self._q.put((req, mm_tokens))

    def add_shard(self, req: Any, sid: int, n_shards: int,
                  idx: np.ndarray, tokens: np.ndarray
                  ) -> Optional[np.ndarray]:
        """Collect one IRP shard; when all ``n_shards`` have arrived,
        align + merge (paper §3.2.2) and return the merged tokens —
        ``None`` while shards are still outstanding."""
        with self._lock:
            # checked under the lock: a sibling shard's failure either
            # happened before (we see finished and retain nothing) or its
            # drop() serializes after our insert and removes it
            if req.finished:
                self._shards.pop(req.req_id, None)
                return None
            shards = self._shards.setdefault(req.req_id, [None] * n_shards)
            shards[sid] = (idx, tokens)
            if any(s is None for s in shards):
                return None
            del self._shards[req.req_id]
        M = req.mm_embeds.shape[0]
        merged = np.zeros((M, tokens.shape[-1]), tokens.dtype)
        for s_idx, s_tok in shards:
            merged[s_idx] = s_tok
        return merged

    def drop(self, req_id: int) -> None:
        """Discard any partial shard assembly for a failed request."""
        with self._lock:
            self._shards.pop(req_id, None)

    def recv(self, timeout: float):
        """Next prefill-ready (req, mm_tokens); raises queue.Empty."""
        return self._q.get(timeout=timeout)

    def recv_nowait(self):
        """Non-blocking variant (scheduler drain); raises queue.Empty."""
        return self._q.get_nowait()

    def qsize(self) -> int:
        """Pending deliveries (least-loaded routing reads queue depth)."""
        return self._q.qsize()

    def drain(self) -> list:
        """Empty the channel (shutdown): every undelivered (req, mm)."""
        return drain_queue(self._q)


class PsiPD:
    """ψ_PD: prefill→decode handoff.

    Paged mode sends a completed ``PrefillProgress`` — the KV stays in
    the shared pool, only the block-table reference moves (the decode
    stage reads the table from the block manager). Dense mode sends
    ``(req, first_tok, cache)`` — a materialized cache move."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue()
        self.transfers = 0

    def send(self, handoff) -> None:
        self.transfers += 1
        self._q.put(handoff)

    def recv_nowait(self):
        """Next handoff; raises queue.Empty when none pending."""
        return self._q.get_nowait()

    def qsize(self) -> int:
        """Unadmitted handoffs (least-loaded routing reads queue depth)."""
        return self._q.qsize()

    def drain(self) -> list:
        """Empty the channel (shutdown): every unadmitted handoff."""
        return drain_queue(self._q)
