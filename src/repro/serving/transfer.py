"""ψ transfer channels between stages (paper §3.1–§3.2.2).

``PsiEP`` is the E→P handoff: it assembles IRP shard outputs into the
merged multimodal-token tensor (align/merge, §3.2.2) and owns the
content-hash-keyed ``MMTokenCache`` (§3.2.1) so a repeated image/audio
payload skips the E stage entirely — the cached merged tokens are
delivered straight to P.

``PsiPD`` is the P→D handoff: in paged mode it carries a block-table
reference (no KV copy), in dense mode it moves the materialized cache.
On real hardware these channels would be device-to-device puts; here they
are typed thread-safe queues with transfer accounting.
"""
from __future__ import annotations

import hashlib
import queue
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np


def drain_queue(q: queue.Queue) -> list:
    """Empty a queue without blocking (shutdown drains)."""
    out = []
    while True:
        try:
            out.append(q.get_nowait())
        except queue.Empty:
            return out


class ShardStream:
    """Streaming ψ_EP assembly state for ONE request (encode–prefill
    overlap): each IRP shard publishes its encoded tokens, with the
    placeholder positions it covers, the moment its forward completes —
    instead of buffering until the full §3.2.2 align/merge. The prefill
    side reads the request's "encoded watermark" (the lowest prompt
    position whose mm token has NOT arrived yet) and advances its chunk
    frontier up to it while later shards are still encoding.

    ``merged`` is set only once every mm token has arrived; that full
    merge — never a partial shard set — is what may be committed to the
    ``MMTokenCache``. The internal lock is a leaf: it is never held
    while taking any other lock."""

    def __init__(self, req: Any):
        self.req = req
        M = int(req.mm_embeds.shape[0])
        self.positions = np.asarray(req.mm_positions,
                                    dtype=np.int64).reshape(-1)
        self._lock = threading.Lock()
        self._have = np.zeros(M, dtype=bool)
        self._buf: Optional[np.ndarray] = None
        self.merged: Optional[np.ndarray] = None

    @property
    def complete(self) -> bool:
        return self.merged is not None

    def publish(self, idx: np.ndarray, tokens: np.ndarray
                ) -> Optional[np.ndarray]:
        """Record one encoded shard; returns the merged tokens when this
        publication completes the set, ``None`` otherwise."""
        with self._lock:
            if self._buf is None:
                self._buf = np.zeros(
                    (self._have.shape[0], tokens.shape[-1]), tokens.dtype)
            self._buf[idx] = tokens
            self._have[idx] = True
            if self.merged is None and bool(self._have.all()):
                self.merged = self._buf
            return self.merged

    def span_ready(self, t0: int, t1: int) -> bool:
        """True when every placeholder position in ``[t0, t1)`` has its
        encoded token — the gate a prefill chunk must pass."""
        with self._lock:
            if self.merged is not None:
                return True
            in_span = (self.positions >= t0) & (self.positions < t1)
            return bool(self._have[in_span].all())

    def watermark(self, total: int) -> int:
        """The encoded watermark: prefill may run up to (not including)
        this prompt position; ``total`` once every shard has landed."""
        with self._lock:
            missing = self.positions[~self._have]
            return int(total) if missing.size == 0 else int(missing.min())

    def fill(self, x: np.ndarray) -> None:
        """Scatter every already-encoded mm token into the embedded
        prompt ``x`` (idempotent). Positions whose shard has not arrived
        keep their placeholder rows — the span gate guarantees no chunk
        covering them runs before a later ``fill`` fixes them up.
        Positions beyond the prompt are dropped, matching the jnp
        ``.at[].set()`` scatter the non-streaming embed path uses."""
        with self._lock:
            if self._buf is None:
                return
            have = self._have & (self.positions < x.shape[0])
            x[self.positions[have]] = self._buf[have]


@dataclass
class PrefillProgress:
    """ψ_PD payload: a request's (possibly partial) prefill state.

    Chunked prefill writes prompt KV into pool blocks chunk-by-chunk;
    this object carries the request between chunks (``n_done`` tokens
    already cached) and, once complete (``done``), travels over ψ_PD to
    the decode stage — the KV never moves, only this reference does.
    ``x`` is the pre-embedded prompt (mm tokens merged at embed time) so
    each chunk is a plain slice; ``mm_tokens`` rides along for the
    preemption requeue path. With prefix caching, ``keys`` carries the
    prompt's hash-chained block keys (committed to the index when the
    prefill completes) and ``n_done`` may start > 0 (cached prefix); a
    FULLY cached prompt arrives at decode with ``first_tok is None`` —
    the first decode step recomputes the last prompt position from
    ``x_last`` to sample it."""
    req: Any
    x: np.ndarray                        # (S, d) embedded prompt inputs
    mm_tokens: Optional[np.ndarray]
    n_done: int = 0                      # prompt tokens already in the pool
    first_tok: Optional[int] = None      # sampled on the final chunk
    keys: Optional[list] = None          # prefix-cache block keys
    stream: Optional[ShardStream] = None  # live ψ_EP stream (overlap)

    def sync_stream(self) -> None:
        """Pull newly published shard tokens into the embedded prompt
        (scheduler thread, before planning a chunk). Once the stream
        completes, ``mm_tokens`` is set to the full merge so preemption
        replay and migration see exactly the non-streaming payload."""
        st = self.stream
        if st is None or self.mm_tokens is not None:
            return
        st.fill(self.x)
        if st.merged is not None:
            self.mm_tokens = st.merged

    def span_blocked(self, t0: int, t1: int) -> bool:
        """True when ``[t0, t1)`` covers a placeholder whose shard has
        not been encoded yet (the chunk must wait at the watermark)."""
        st = self.stream
        if st is None or self.mm_tokens is not None:
            return False
        return not st.span_ready(t0, t1)

    @property
    def x_last(self) -> np.ndarray:
        return self.x[-1]

    @property
    def total(self) -> int:
        return self.x.shape[0]

    @property
    def done(self) -> bool:
        return self.n_done >= self.total


@dataclass
class MigratedPrefill:
    """ψ_PD payload BETWEEN instances: the prompt KV copied out of the
    prefill worker's pool (the paper's PD cache migration), waiting to be
    injected into a decode worker's pool. Once injected, the decode stage
    admits it exactly like a local ``PrefillProgress`` (same ``req`` /
    ``first_tok`` / ``total`` / ``mm_tokens`` surface); ``k_blocks`` /
    ``v_blocks`` are dropped after injection to release the copy."""
    req: Any
    first_tok: Optional[int]
    total: int                           # prompt tokens already prefetched
    mm_tokens: Optional[np.ndarray]
    k_blocks: Optional[np.ndarray]       # (L, nb, bs, K, hd)
    v_blocks: Optional[np.ndarray]
    keys: Optional[list] = None          # prefix-cache block keys (re-pin)
    x_last: Optional[np.ndarray] = None  # embedded last prompt token
    #                                      (fully-cached handoff only)


class MMTokenCache:
    """Content-hash-keyed LRU cache of merged multimodal tokens.

    Paper §3.2.1: "cache multimedia tokens for efficient transfer" — the
    key is a digest of the raw modality payload, so identical images or
    audio clips (byte-identical embeddings) across requests reuse the
    encoded tokens and the E stage runs zero shards."""

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self._entries: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def content_key(mm_embeds: np.ndarray) -> str:
        a = np.ascontiguousarray(mm_embeds)
        h = hashlib.sha1(a.tobytes())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        return h.hexdigest()

    def get(self, key: str) -> Optional[np.ndarray]:
        with self._lock:
            tokens = self._entries.get(key)
            if tokens is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return tokens

    def put(self, key: str, tokens: np.ndarray, *,
            n_expected: Optional[int] = None) -> None:
        """Commit merged tokens. Streaming ψ_EP makes partial shard sets
        a real hazard — a truncated entry would poison every dedup
        follower — so callers pass the request's full mm token count and
        a mismatch is refused."""
        if tokens is None:
            raise ValueError("mm cache put: tokens must be a merged array")
        if n_expected is not None and int(tokens.shape[0]) != int(n_expected):
            raise ValueError(
                f"mm cache put refused: {int(tokens.shape[0])} of "
                f"{int(n_expected)} mm tokens — a partial/streaming merge "
                f"must never be cached")
        if self.capacity <= 0:
            return
        with self._lock:
            self._entries[key] = tokens
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)


class PsiEP:
    """ψ_EP: multimodal-token handoff from E workers to the P stage."""

    def __init__(self, cache: MMTokenCache):
        self.cache = cache
        self._q: queue.Queue = queue.Queue()
        self._shards: dict[int, list] = {}
        self._streams: dict[int, ShardStream] = {}
        self._lock = threading.Lock()
        self.transfers = 0

    def send(self, req: Any, mm_tokens) -> None:
        """Deliver a prefill-ready request: merged tokens, a cache hit,
        a text-only request, a preemption requeue — or, with overlap, a
        live ``ShardStream`` whose shards are still encoding."""
        self.transfers += 1
        self._q.put((req, mm_tokens))

    def open_stream(self, req: Any) -> ShardStream:
        """Switch a request's ψ_EP assembly to streaming publication:
        subsequent ``add_shard`` calls publish into the stream (visible
        to an already-admitted prefill) instead of buffering."""
        stream = ShardStream(req)
        with self._lock:
            self._streams[req.req_id] = stream
        return stream

    def has_stream(self, req_id: int) -> bool:
        with self._lock:
            return req_id in self._streams

    def add_shard(self, req: Any, sid: int, n_shards: int,
                  idx: np.ndarray, tokens: np.ndarray
                  ) -> Optional[np.ndarray]:
        """Collect one IRP shard; when all ``n_shards`` have arrived,
        align + merge (paper §3.2.2) and return the merged tokens —
        ``None`` while shards are still outstanding. With a registered
        stream the shard is published immediately (encode–prefill
        overlap); the return contract is unchanged."""
        with self._lock:
            # checked under the lock: a sibling shard's failure either
            # happened before (we see finished and retain nothing) or its
            # drop() serializes after our insert and removes it
            if req.finished:
                self._shards.pop(req.req_id, None)
                self._streams.pop(req.req_id, None)
                return None
            stream = self._streams.get(req.req_id)
            if stream is None:
                shards = self._shards.setdefault(
                    req.req_id, [None] * n_shards)
                shards[sid] = (idx, tokens)
                if any(s is None for s in shards):
                    return None
                del self._shards[req.req_id]
        if stream is not None:
            # publish outside our lock — the stream lock is a leaf
            merged = stream.publish(idx, tokens)
            if merged is not None:
                with self._lock:
                    self._streams.pop(req.req_id, None)
            return merged
        M = req.mm_embeds.shape[0]
        merged = np.zeros((M, tokens.shape[-1]), tokens.dtype)
        for s_idx, s_tok in shards:
            merged[s_idx] = s_tok
        return merged

    def drop(self, req_id: int) -> None:
        """Discard any partial shard assembly for a failed request."""
        with self._lock:
            self._shards.pop(req_id, None)
            self._streams.pop(req_id, None)

    def recv(self, timeout: float):
        """Next prefill-ready (req, mm_tokens); raises queue.Empty."""
        return self._q.get(timeout=timeout)

    def recv_nowait(self):
        """Non-blocking variant (scheduler drain); raises queue.Empty."""
        return self._q.get_nowait()

    def qsize(self) -> int:
        """Pending deliveries (least-loaded routing reads queue depth)."""
        return self._q.qsize()

    def drain(self) -> list:
        """Empty the channel (shutdown): every undelivered (req, mm)."""
        return drain_queue(self._q)


class PsiPD:
    """ψ_PD: prefill→decode handoff.

    Paged mode sends a completed ``PrefillProgress`` — the KV stays in
    the shared pool, only the block-table reference moves (the decode
    stage reads the table from the block manager). Dense mode sends
    ``(req, first_tok, cache)`` — a materialized cache move."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue()
        self.transfers = 0

    def send(self, handoff) -> None:
        self.transfers += 1
        self._q.put(handoff)

    def recv_nowait(self):
        """Next handoff; raises queue.Empty when none pending."""
        return self._q.get_nowait()

    def qsize(self) -> int:
        """Unadmitted handoffs (least-loaded routing reads queue depth)."""
        return self._q.qsize()

    def drain(self) -> list:
        """Empty the channel (shutdown): every unadmitted handoff."""
        return drain_queue(self._q)
