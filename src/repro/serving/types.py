"""Typed serving primitives: sampling params, request lifecycle, handles.

The stage graph (paper §3.1) moves a request through explicit states:

    QUEUED -> ENCODING -> PREFILLING -> DECODING -> DONE
         \\______________/^    ^____________|   \\-> FAILED
          (text-only / mm      (preemption requeues
           cache hit skip E)    through P)

``ServeRequest`` carries the request through the E/P/D stages and doubles
as the result object; ``RequestHandle`` is what ``EPDEngine.submit``
returns — blocking ``result()`` or an incremental ``stream()`` iterator.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional

import numpy as np


class APIError(ValueError):
    """Invalid request payload or parameters."""


class RequestTimeout(TimeoutError):
    """A ``result()``/``stream()`` wait ran out of time.

    Distinct from a FAILED request (which ``result()`` returns and
    ``stream()`` surfaces as RuntimeError): on a timeout the request is
    still live server-side — the caller decides whether to keep waiting
    or ``abort()`` it. The HTTP gateway maps this to 408."""

    def __init__(self, req_id: int, waited: float):
        super().__init__(
            f"request {req_id}: no terminal state within {waited:.1f}s")
        self.req_id = req_id
        self.waited = waited


@dataclass(frozen=True)
class SamplingParams:
    """Decode-head sampling controls (OpenAI-style semantics).

    ``temperature == 0`` is exact greedy (bit-identical to argmax);
    otherwise nucleus (top-p) sampling with a per-request PRNG seed, so
    the same request replayed — including after a preemption — emits the
    same tokens. A sampled token matching ``eos_id`` or any entry of
    ``stop_tokens`` finishes the request with ``FinishReason.STOP``; the
    matched token itself is not emitted (OpenAI "stop" semantics)."""
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0
    stop_tokens: tuple = ()
    eos_id: Optional[int] = None

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def is_stop(self, tok: int) -> bool:
        return tok == self.eos_id or tok in self.stop_tokens

    def validate(self) -> None:
        if not (0.0 <= self.temperature <= 2.0):
            raise APIError(f"temperature out of range: {self.temperature}")
        if not (0.0 < self.top_p <= 1.0):
            raise APIError(f"top_p out of range: {self.top_p}")
        if not (0 <= self.seed < 2 ** 32):   # becomes a uint32 PRNG seed
            raise APIError(f"seed must be a uint32: {self.seed}")
        for t in (*self.stop_tokens,
                  *(() if self.eos_id is None else (self.eos_id,))):
            # accept numpy integers (token ids sliced out of a prompt
            # array), reject bools masquerading as ints
            if (isinstance(t, bool) or not isinstance(t, (int, np.integer))
                    or t < 0):
                raise APIError(f"stop/eos token ids must be ints >= 0: {t!r}")


class RequestState(enum.Enum):
    QUEUED = "queued"
    ENCODING = "encoding"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    FAILED = "failed"


# legal lifecycle transitions; DECODING -> PREFILLING is preemption,
# QUEUED -> FAILED is abort-before-admission
_TRANSITIONS: dict[RequestState, tuple[RequestState, ...]] = {
    RequestState.QUEUED: (RequestState.ENCODING, RequestState.PREFILLING,
                          RequestState.FAILED),
    RequestState.ENCODING: (RequestState.PREFILLING, RequestState.FAILED),
    RequestState.PREFILLING: (RequestState.DECODING, RequestState.FAILED),
    RequestState.DECODING: (RequestState.DONE, RequestState.PREFILLING,
                            RequestState.FAILED),
    RequestState.DONE: (),
    RequestState.FAILED: (),
}


class FinishReason(enum.Enum):
    LENGTH = "length"
    STOP = "stop"
    ERROR = "error"


@dataclass
class EngineConfig:
    n_encode_workers: int = 2          # IRP degree
    max_new_tokens: int = 16
    decode_batch: int = 8              # fixed decode slots (paged mode)
    cache_headroom: int = 64           # dense mode only
    # paged decode stage
    mode: str = "paged"                # "paged" | "dense"
    kv_blocks: int = 256               # shared pool size (blocks)
    kv_block_size: int = 16            # tokens per block
    max_seq_len: int = 256             # block-table width cap per sequence
    # ψ_EP multimedia-token cache (paper §3.2.1); 0 disables caching
    mm_cache_entries: int = 32
    # continuous-batching scheduler (paged mode): prompts longer than
    # ``prefill_chunk`` prefill chunk-by-chunk between decode steps
    # (0 = unchunked — whole prompt in one call, the stall baseline);
    # ``step_token_budget`` caps tokens per scheduler iteration across
    # decode slots + prefill chunks (0 = decode_batch + prefill_chunk;
    # values below that floor are clamped up to it — a smaller budget
    # would silently starve prefill whenever decode is busy)
    prefill_chunk: int = 64
    step_token_budget: int = 0
    # execution backend + runner selection (paged mode):
    # ``attn_backend`` names an attention backend from
    # ``repro.kernels.registry`` ("ref" | "pallas"); None defers to the
    # REPRO_ATTN_BACKEND env var, then the platform default (pallas
    # compiled on TPU, ref elsewhere). ``runner`` picks the P/D execution
    # path: "packed" = ONE token-packed jitted forward per scheduler
    # iteration over decode slots + prefill chunks (the ModelRunner);
    # "two_program" = the historical decode-step-then-chunk-steps path,
    # kept as the parity oracle.
    attn_backend: Optional[str] = None
    runner: str = "packed"
    # block-level KV prefix caching (paged mode): hash-chained block keys
    # over the prompt (mm-content salt folded into the chain root so it
    # composes with the ψ_EP cache), per-block refcounts, LRU eviction of
    # unreferenced cached blocks, copy-on-write on divergence. Off-path
    # is byte-identical to today; greedy streams are bit-identical with
    # the cache on vs off on every topology.
    prefix_cache: bool = False
    # encode–prefill overlap (intra-request pipelining, RServe-style):
    # ``encode_overlap`` streams each completed IRP shard over ψ_EP the
    # moment it finishes, and the scheduler advances the request's
    # chunked-prefill frontier up to its encoded watermark while later
    # shards are still encoding — the merge is lossless (§3.2.2), so
    # greedy streams stay bit-identical overlap-on vs off. A no-op for
    # text-only and single-shard requests. ``encode_lanes`` (packed
    # runner only) additionally folds the encoder forwards into the
    # packed per-iteration plan as patch-group segment rows co-scheduled
    # with decode slots + prefill chunks under ``step_token_budget`` —
    # ONE jitted program per iteration across all three stages.
    encode_overlap: bool = False
    encode_lanes: bool = False


@dataclass
class ClusterConfig:
    """Multi-instance cluster topology + role-switching knobs.

    ``spec`` uses the paper's notation: ``"2E1P1D"`` is true EPD
    disaggregation, ``"4EPD"`` reproduces the vLLM aggregated baseline,
    ``"3EP1D"`` DistServe. Each instance runs the stages of its role on
    one serialized executor thread over its OWN KV/MM pools (sized by the
    per-instance ``EngineConfig``); ψ_EP moves merged multimodal tokens
    and ψ_PD migrates prompt KV between instances.

    Role switching (paper §3.2.4) re-roles an idle single-letter instance
    when the ``LoadEstimator``'s per-stage demand shifts: drain -> swap
    stage set/pools -> cooldown. ``monitor_interval`` is how often the
    monitor thread re-evaluates; ``switch_cooldown`` is the anti-thrash
    window an instance sits out after switching. A stage never drops to
    zero instances (donors need >= 2 of their letter).

    Elastic scaling (``elastic=True``) lets the supervisor *add/remove*
    instances (ElasticMM-style) instead of only re-roling fixed ones:
    when the ``LoadEstimator``'s per-device utilization for a stage
    crosses ``scale_up_util`` a new instance of that letter is spawned
    (fleet capped at ``max_instances``); below ``scale_down_util`` the
    idlest multi-instance stage drains one instance (never below
    ``min_instances`` total, never to zero of a served letter), with
    ``scale_cooldown`` seconds between decisions."""
    spec: str = "1EPD"
    assign_policy: str = "least_loaded"     # round_robin | latency_aware
    role_switch: bool = False
    monitor_interval: float = 0.25          # seconds (real-time monitor)
    switch_cooldown: float = 1.0            # anti-thrash, seconds
    elastic: bool = False
    scale_up_util: float = 0.9              # device-sec/sec per instance
    scale_down_util: float = 0.3
    min_instances: int = 1
    max_instances: int = 8
    scale_cooldown: float = 1.0             # seconds between scale ops


@dataclass
class ServeRequest:
    """One request's journey through the stage graph (also the result)."""
    req_id: int
    prompt: np.ndarray                       # (S,) int32
    mm_embeds: Optional[np.ndarray] = None   # (M, d_frontend)
    mm_positions: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # lifecycle
    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[FinishReason] = None
    error: Optional[str] = None
    mm_cache_hit: bool = False
    # timestamps
    t_submit: float = 0.0
    t_encoded: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    tokens: list[int] = field(default_factory=list)
    n_preemptions: int = 0
    stop_hit: bool = False          # a stop/eos token was sampled
    # streaming consumers wait on this for new tokens / terminal state
    _cv: threading.Condition = field(default_factory=threading.Condition,
                                     repr=False, compare=False)

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        n = len(self.tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)

    @property
    def finished(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.FAILED)

    # ------------------------------------------------------------ lifecycle
    def advance(self, new_state: RequestState) -> None:
        """Atomic under ``_cv``: an external ``abort`` (mark_failed) and a
        stage-thread advance must serialize, or a racing advance could
        overwrite the FAILED state and resurrect the request."""
        with self._cv:
            self._advance(new_state)

    def _advance(self, new_state: RequestState) -> None:
        if new_state not in _TRANSITIONS[self.state]:
            raise ValueError(
                f"request {self.req_id}: illegal transition "
                f"{self.state.value} -> {new_state.value}")
        self.state = new_state

    def emit(self, tok: int) -> None:
        """Append a generated token and wake streaming consumers.

        No-op once terminal: an abort can land between a runner step
        sampling a token and committing it, and a late token appended to
        a FAILED request would leak into a concurrently-open stream."""
        with self._cv:
            if self.finished:
                return
            self.tokens.append(int(tok))
            self._cv.notify_all()

    def accept(self, tok: int) -> bool:
        """Record one sampled token; returns True when generation is over.

        Stop/eos tokens are latched (``stop_hit``) but NOT emitted —
        OpenAI "stop" semantics exclude the matched token — so streams
        simply terminate. The retire path turns ``stop_hit`` into
        ``FinishReason.STOP`` (vs LENGTH). A request aborted mid-step
        reports finished immediately so the decode sweep retires it."""
        if self.finished:
            return True
        if self.sampling.is_stop(int(tok)):
            self.stop_hit = True
            return True
        self.emit(tok)
        return len(self.tokens) >= self.max_new_tokens

    @property
    def done_generating(self) -> bool:
        """Decode retire condition (stop token or length budget)."""
        return self.stop_hit or len(self.tokens) >= self.max_new_tokens

    def reset_generation(self) -> None:
        """Preemption: drop generated tokens; the deterministic replay
        (greedy, or seeded sampling keyed on token index) re-emits the
        identical prefix, so open streams resume seamlessly."""
        with self._cv:
            self.tokens.clear()
            self.stop_hit = False
            self.n_preemptions += 1

    def mark_done(self, reason: FinishReason) -> None:
        with self._cv:
            self.finish_reason = reason
            self._advance(RequestState.DONE)
            self._cv.notify_all()

    def mark_failed(self, error: str) -> bool:
        """Atomically claim the FAILED state; returns False if the request
        already reached a terminal state (e.g. a sibling IRP shard failed
        it first), so concurrent failers can't double-transition."""
        with self._cv:
            if self.finished:
                return False
            self.error = error
            self.finish_reason = FinishReason.ERROR
            self._advance(RequestState.FAILED)
            self._cv.notify_all()
            return True


@dataclass
class RequestHandle:
    """Returned by ``EPDEngine.submit`` — the client's view of a request."""
    req: ServeRequest
    engine: Any

    @property
    def req_id(self) -> int:
        return self.req.req_id

    def result(self, timeout: float = 300.0) -> ServeRequest:
        """Block until the request completes; returns the ServeRequest.

        Safe to call after (or instead of) consuming ``stream()``, and
        safe concurrently WITH a stream consumer — the wait is on the
        request's own terminal state, not a registry entry a concurrent
        collector could steal."""
        return self.engine._result_of(self.req, timeout)

    def stream(self, timeout: float = 300.0) -> Iterator[int]:
        """Yield tokens incrementally as the decode stage emits them.

        Works even after ``result()`` collected the request — the handle
        holds the request, not a registry lookup."""
        return self.engine._stream(self.req, timeout)
