"""Request frontend: OpenAI-style multimodal chat-completions schema.

Paper App. E: "The API interface adheres to OpenAI's multimodal
specifications, enabling users to specify parameters such as output length,
temperature, and multimodal data inputs." This module validates/normalizes
such payloads into ``ServeRequest``s for the engine (and ``Request``s for
the simulator) — no HTTP server is started in this offline container, but
the schema layer is the real one a deployment would mount behind a router.

``chat_completion(engine, payload)`` is the full round trip: parse →
submit → wait → an OpenAI-shaped response dict with ``choices``/``usage``
plus a ``timings`` block (ttft, tpot, n_preemptions, mm_cache_hit) so
benchmarks and examples never poke ``ServeRequest`` internals.
"""
from __future__ import annotations

import itertools
import zlib
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.request import Request, SLO
from repro.serving.types import APIError, SamplingParams, ServeRequest

__all__ = ["APIError", "CompletionParams", "parse_chat_request",
           "chat_completion", "build_chat_response", "build_chat_chunk",
           "IncrementalDetokenizer", "to_sim_request", "sim_request_of"]


@dataclass
class CompletionParams:
    max_tokens: int = 16
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: tuple = ()
    eos_token_id: Optional[int] = None

    def to_sampling(self) -> SamplingParams:
        return SamplingParams(temperature=self.temperature, top_p=self.top_p,
                              seed=self.seed,
                              stop_tokens=tuple(self.stop_token_ids),
                              eos_id=self.eos_token_id)

    def validate(self) -> None:
        if not (1 <= self.max_tokens <= 8192):
            raise APIError(f"max_tokens out of range: {self.max_tokens}")
        self.to_sampling().validate()


_IDS = itertools.count(1)


def parse_chat_request(cfg: ArchConfig, payload: dict) -> ServeRequest:
    """OpenAI-style payload -> ServeRequest.

    Expected shape (subset of the OpenAI multimodal spec):
      {"messages": [{"role": "user", "content": [
          {"type": "text", "text": "..."} |
          {"type": "image_embedding", "embedding": [[...], ...]} ]}],
       "max_tokens": 16, "temperature": 0.0, "top_p": 1.0, "seed": 0,
       "stop_token_ids": [7, 9], "eos_token_id": 2}

    ``stop_token_ids``/``eos_token_id`` end generation with
    ``finish_reason == "stop"`` when sampled (the toy tokenizer has no
    string detokenizer, so stops are token ids, not OpenAI's "stop"
    strings — same semantics: the matched token is not emitted).
    Image/audio payloads arrive as PRECOMPUTED embeddings (the modality
    frontend is stubbed per DESIGN.md); a deployment would put the
    patchifier in front of this layer. ``temperature``/``top_p``/``seed``
    are carried on the request and honored by the decode stage
    (temperature 0 = exact greedy).
    """
    if "messages" not in payload or not payload["messages"]:
        raise APIError("missing messages")
    eos = payload.get("eos_token_id")
    params = CompletionParams(
        max_tokens=int(payload.get("max_tokens", 16)),
        temperature=float(payload.get("temperature", 0.0)),
        top_p=float(payload.get("top_p", 1.0)),
        seed=int(payload.get("seed", 0)),
        stop_token_ids=tuple(int(t) for t in
                             payload.get("stop_token_ids", ())),
        eos_token_id=None if eos is None else int(eos))
    params.validate()

    text_parts: list[str] = []
    embeds: list[np.ndarray] = []
    for msg in payload["messages"]:
        content = msg.get("content", [])
        if isinstance(content, str):
            content = [{"type": "text", "text": content}]
        for part in content:
            kind = part.get("type")
            if kind == "text":
                text_parts.append(part["text"])
            elif kind in ("image_embedding", "audio_embedding"):
                if cfg.modality is None:
                    raise APIError(
                        f"{cfg.name} is text-only; got {kind}")
                arr = np.asarray(part["embedding"], np.float32)
                if arr.ndim != 2 or arr.shape[1] != cfg.modality.enc_d_model:
                    raise APIError(
                        f"embedding must be (tokens, {cfg.modality.enc_d_model})"
                        f", got {arr.shape}")
                embeds.append(arr)
            else:
                raise APIError(f"unknown content type {kind!r}")

    prompt = _toy_tokenize(" ".join(text_parts), cfg.vocab)
    mm = np.concatenate(embeds, axis=0) if embeds else None
    pos = (np.arange(1, mm.shape[0] + 1, dtype=np.int32)
           if mm is not None else None)
    total = len(prompt) + (mm.shape[0] if mm is not None else 0) \
        + params.max_tokens
    if total > cfg.max_context:
        raise APIError(f"request needs {total} tokens; context limit is "
                       f"{cfg.max_context} (OOCL)")
    return ServeRequest(
        req_id=next(_IDS), prompt=prompt, mm_embeds=mm, mm_positions=pos,
        max_new_tokens=params.max_tokens, sampling=params.to_sampling())


def _toy_tokenize(text: str, vocab: int) -> np.ndarray:
    """Deterministic stand-in tokenizer (crc32 per whitespace word).

    crc32 is seedless and stable across processes — Python's ``hash()``
    is salted per interpreter, so the same payload would tokenize
    differently across runs."""
    words = text.split() or ["<empty>"]
    return np.asarray(
        [zlib.crc32(w.encode("utf-8")) % max(vocab - 3, 1) + 2
         for w in words], np.int32)


# ------------------------------------------------------------- responses
def build_chat_response(cfg: ArchConfig, req: ServeRequest) -> dict:
    """OpenAI-shaped chat.completion response for a finished request.

    The toy tokenizer has no detokenizer, so ``content`` renders the raw
    token ids; ``token_ids`` carries them structurally. ``timings`` adds
    the serving metrics the paper reports (TTFT/TPOT) plus the EPD
    bookkeeping callers previously dug out of engine internals."""
    n_mm = 0 if req.mm_embeds is None else int(req.mm_embeds.shape[0])
    n_out = len(req.tokens)
    n_prompt = len(req.prompt) + n_mm
    return {
        "id": f"chatcmpl-{req.req_id}",
        "object": "chat.completion",
        "model": cfg.name,
        "choices": [{
            "index": 0,
            "message": {"role": "assistant",
                        "content": " ".join(str(t) for t in req.tokens)},
            "token_ids": list(req.tokens),
            "finish_reason": (req.finish_reason.value
                              if req.finish_reason else None),
        }],
        "usage": {"prompt_tokens": n_prompt,
                  "completion_tokens": n_out,
                  "total_tokens": n_prompt + n_out},
        "timings": {"ttft": req.ttft,
                    "tpot": req.tpot,
                    "n_preemptions": req.n_preemptions,
                    "mm_cache_hit": req.mm_cache_hit},
    }


class IncrementalDetokenizer:
    """Token → text deltas for streaming responses.

    Concatenating every ``feed()`` return value yields byte-identical
    text to ``build_chat_response``'s ``content`` field
    (``" ".join(str(t) for t in tokens)``), so a client assembling SSE
    deltas reconstructs exactly the non-streaming response. A real
    tokenizer would need the usual held-back-byte machinery (partial
    UTF-8 sequences); the toy token-id rendering keeps the seam without
    it."""

    def __init__(self):
        self._n = 0

    def feed(self, tok: int) -> str:
        piece = str(int(tok)) if self._n == 0 else " " + str(int(tok))
        self._n += 1
        return piece


def build_chat_chunk(cfg: ArchConfig, req: ServeRequest,
                     delta: Optional[str] = None, *, role: bool = False,
                     finish_reason: Optional[str] = None) -> dict:
    """OpenAI-shaped chat.completion.chunk for one SSE event."""
    d: dict[str, Any] = {}
    if role:
        d["role"] = "assistant"
    if delta is not None:
        d["content"] = delta
    return {
        "id": f"chatcmpl-{req.req_id}",
        "object": "chat.completion.chunk",
        "model": cfg.name,
        "choices": [{"index": 0, "delta": d,
                     "finish_reason": finish_reason}],
    }


def chat_completion(engine, payload: dict, timeout: float = 600.0) -> dict:
    """Blocking round trip: payload -> engine -> chat.completion dict.

    Raises RuntimeError if the request FAILED server-side (a deployment
    would map this to a 5xx), so callers never see a response with
    nonsense timings."""
    req = parse_chat_request(engine.cfg, payload)
    handle = engine.submit(req)
    out = handle.result(timeout=timeout)
    if out.error is not None:
        raise RuntimeError(f"request {out.req_id} failed: {out.error}")
    return build_chat_response(engine.cfg, req)


def sim_request_of(cfg: ArchConfig, sreq: ServeRequest, arrival: float,
                   slo: Optional[SLO] = None) -> Request:
    """ServeRequest -> simulator ``Request`` (same logical workload in the
    simulator's dialect). Used for capacity planning, by the cluster
    engine's LoadEstimator feed, and by the sim-vs-real cross-validation
    tests — keeping the two dialects convertible is what makes the
    structural metrics comparable."""
    m = cfg.modality
    n_tokens = 0 if sreq.mm_embeds is None else sreq.mm_embeds.shape[0]
    tpi = m.tokens_per_item if m else 1
    return Request(
        req_id=sreq.req_id, arrival=arrival,
        prompt_len=len(sreq.prompt),
        n_items=-(-n_tokens // tpi) if n_tokens else 0,
        patches_per_item=1,
        tokens_per_patch=tpi,
        output_len=sreq.max_new_tokens, slo=slo)


def to_sim_request(cfg: ArchConfig, payload: dict, arrival: float,
                   slo: Optional[SLO] = None) -> Request:
    """Same payload -> simulator Request (for capacity planning)."""
    return sim_request_of(cfg, parse_chat_request(cfg, payload), arrival,
                          slo)
