"""Request frontend: OpenAI-style multimodal chat-completions schema.

Paper App. E: "The API interface adheres to OpenAI's multimodal
specifications, enabling users to specify parameters such as output length,
temperature, and multimodal data inputs." This module validates/normalizes
such payloads into ``ServeRequest``s for the engine (and ``Request``s for
the simulator) — no HTTP server is started in this offline container, but
the schema layer is the real one a deployment would mount behind a router.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.request import Request, SLO
from repro.serving.engine import ServeRequest


class APIError(ValueError):
    pass


@dataclass
class CompletionParams:
    max_tokens: int = 16
    temperature: float = 0.0
    top_p: float = 1.0

    def validate(self) -> None:
        if not (1 <= self.max_tokens <= 8192):
            raise APIError(f"max_tokens out of range: {self.max_tokens}")
        if not (0.0 <= self.temperature <= 2.0):
            raise APIError(f"temperature out of range: {self.temperature}")
        if not (0.0 < self.top_p <= 1.0):
            raise APIError(f"top_p out of range: {self.top_p}")


_IDS = itertools.count(1)


def parse_chat_request(cfg: ArchConfig, payload: dict) -> ServeRequest:
    """OpenAI-style payload -> ServeRequest.

    Expected shape (subset of the OpenAI multimodal spec):
      {"messages": [{"role": "user", "content": [
          {"type": "text", "text": "..."} |
          {"type": "image_embedding", "embedding": [[...], ...]} ]}],
       "max_tokens": 16, "temperature": 0.0}
    Image/audio payloads arrive as PRECOMPUTED embeddings (the modality
    frontend is stubbed per DESIGN.md); a deployment would put the
    patchifier in front of this layer.
    """
    if "messages" not in payload or not payload["messages"]:
        raise APIError("missing messages")
    params = CompletionParams(
        max_tokens=int(payload.get("max_tokens", 16)),
        temperature=float(payload.get("temperature", 0.0)),
        top_p=float(payload.get("top_p", 1.0)))
    params.validate()

    text_parts: list[str] = []
    embeds: list[np.ndarray] = []
    for msg in payload["messages"]:
        content = msg.get("content", [])
        if isinstance(content, str):
            content = [{"type": "text", "text": content}]
        for part in content:
            kind = part.get("type")
            if kind == "text":
                text_parts.append(part["text"])
            elif kind in ("image_embedding", "audio_embedding"):
                if cfg.modality is None:
                    raise APIError(
                        f"{cfg.name} is text-only; got {kind}")
                arr = np.asarray(part["embedding"], np.float32)
                if arr.ndim != 2 or arr.shape[1] != cfg.modality.enc_d_model:
                    raise APIError(
                        f"embedding must be (tokens, {cfg.modality.enc_d_model})"
                        f", got {arr.shape}")
                embeds.append(arr)
            else:
                raise APIError(f"unknown content type {kind!r}")

    prompt = _toy_tokenize(" ".join(text_parts), cfg.vocab)
    mm = np.concatenate(embeds, axis=0) if embeds else None
    pos = (np.arange(1, mm.shape[0] + 1, dtype=np.int32)
           if mm is not None else None)
    total = len(prompt) + (mm.shape[0] if mm is not None else 0) \
        + params.max_tokens
    if total > cfg.max_context:
        raise APIError(f"request needs {total} tokens; context limit is "
                       f"{cfg.max_context} (OOCL)")
    return ServeRequest(req_id=next(_IDS), prompt=prompt, mm_embeds=mm,
                        mm_positions=pos, max_new_tokens=params.max_tokens)


def _toy_tokenize(text: str, vocab: int) -> np.ndarray:
    """Deterministic stand-in tokenizer (hash per whitespace word)."""
    words = text.split() or ["<empty>"]
    return np.asarray([hash(w) % max(vocab - 3, 1) + 2 for w in words],
                      np.int32)


def to_sim_request(cfg: ArchConfig, payload: dict, arrival: float,
                   slo: Optional[SLO] = None) -> Request:
    """Same payload -> simulator Request (for capacity planning)."""
    sreq = parse_chat_request(cfg, payload)
    m = cfg.modality
    n_tokens = 0 if sreq.mm_embeds is None else sreq.mm_embeds.shape[0]
    tpi = m.tokens_per_item if m else 1
    return Request(
        req_id=sreq.req_id, arrival=arrival,
        prompt_len=len(sreq.prompt),
        n_items=-(-n_tokens // tpi) if n_tokens else 0,
        patches_per_item=1,
        tokens_per_patch=tpi,
        output_len=sreq.max_new_tokens, slo=slo)
