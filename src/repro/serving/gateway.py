"""Asyncio HTTP serving gateway (OpenAI-shaped, stdlib-only).

The front door the ROADMAP's production north-star was missing: a real
HTTP server over the engine API, so SLO attainment is measured against
live traffic instead of Python calls. Endpoints:

  POST /v1/chat/completions   JSON chat completion; ``"stream": true``
                              switches to SSE (``data: {chunk}`` events,
                              ``data: [DONE]`` sentinel)
  GET  /health                liveness + per-backend pressure snapshot
  GET  /metrics               ServeStats counters (prefix cache, packed
                              runner, aborts, ...) + gateway counters

``frontend`` is duck-typed: a single engine (``EPDEngine`` /
``ClusterEngine``) or a ``serving.lb.LoadBalancer`` fleet — anything
with ``cfg`` / ``submit`` / ``abort`` / ``stats`` / ``health``.

Three design points carry the load:

  * **Off-thread detokenization**: the asyncio loop never blocks on the
    engine. Every ``result()`` wait, incremental ``stream()`` iteration,
    token→text conversion and SSE chunk assembly runs on a small
    ``ThreadPoolExecutor`` (the detokenizer pool), feeding bytes back to
    the loop through a queue — many tiny streaming responses cannot
    stall the packed scheduler loop, which shares no thread with any of
    this.
  * **Cancellation plumbing**: a disconnect watcher task notices client
    EOF mid-response and calls ``frontend.abort`` — the engine-side
    abort path releases the request's KV blocks and ψ-channel state, so
    a hung-up client cannot strand pool capacity.
  * **Bounded admission**: ``max_concurrent`` requests run at once; up
    to ``max_queue`` more may wait; beyond that the gateway answers 429
    immediately (overload sheds load at the door, it does not build an
    unbounded queue).

HTTP status mapping: malformed JSON / schema / parameter errors → 400
(via ``CompletionParams.validate`` inside ``parse_chat_request``),
unknown path → 404, bad method → 405, ``RequestTimeout`` → 408,
admission queue full → 429, server-side request failure → 500.
"""
from __future__ import annotations

import asyncio
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

from repro.serving.api import (APIError, IncrementalDetokenizer,
                               build_chat_chunk, build_chat_response,
                               parse_chat_request)
from repro.serving.types import RequestTimeout

__all__ = ["GatewayServer"]

_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 408: "Request Timeout",
           429: "Too Many Requests", 500: "Internal Server Error"}

_DISCONNECT = object()        # queue sentinel: client hung up


def _json_bytes(obj: Any) -> bytes:
    return json.dumps(obj, default=str).encode("utf-8")


def _response(status: int, body: bytes,
              content_type: str = "application/json") -> bytes:
    return (f"HTTP/1.1 {status} {_STATUS[status]}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode("latin1") + body


def _error_body(status: int, message: str) -> bytes:
    return _json_bytes({"error": {"message": message, "code": status}})


def _sse(obj: Any) -> bytes:
    return b"data: " + _json_bytes(obj) + b"\n\n"


class _EngineFailure(RuntimeError):
    """Request reached FAILED server-side (gateway maps to 500)."""


class GatewayServer:
    """Threaded asyncio HTTP server over a serving frontend.

    ``start()`` spins the event loop up on a dedicated thread and blocks
    until the listening port is bound (``port=0`` picks an ephemeral
    port; read ``self.port`` afterwards), so synchronous callers — tests,
    examples, benchmark drivers — can use plain ``http.client`` against
    it. ``stop()`` shuts the loop and the detokenizer pool down."""

    def __init__(self, frontend: Any, host: str = "127.0.0.1",
                 port: int = 0, *, max_concurrent: int = 8,
                 max_queue: int = 32, detok_workers: Optional[int] = None,
                 request_timeout: float = 300.0):
        self.frontend = frontend
        self.host = host
        self.port = port
        self.max_concurrent = max_concurrent
        self.max_queue = max_queue
        self.request_timeout = request_timeout
        # each admitted request holds at most ONE detok-pool job (a unary
        # result wait or a stream worker), so max_concurrent workers can
        # never head-of-line block an admitted stream behind another
        self._pool = ThreadPoolExecutor(
            max_workers=detok_workers or max_concurrent,
            thread_name_prefix="detok")
        self._thread: Optional[threading.Thread] = None
        self._ready = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._shutdown: Optional[asyncio.Event] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._waiting = 0
        self.counters = {"requests": 0, "completions": 0, "streams": 0,
                         "rejected_400": 0, "rejected_429": 0,
                         "timeouts_408": 0, "disconnects": 0,
                         "failures_500": 0}

    # ----------------------------------------------------------- lifecycle
    def start(self, timeout: float = 30.0) -> "GatewayServer":
        self._thread = threading.Thread(target=self._run_loop, daemon=True,
                                        name="gateway")
        self._thread.start()
        if not self._ready.wait(timeout):
            raise RuntimeError("gateway failed to bind within timeout")
        return self

    def stop(self) -> None:
        if self._loop is not None and self._shutdown is not None:
            self._loop.call_soon_threadsafe(self._shutdown.set)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self._pool.shutdown(wait=False)

    def _run_loop(self) -> None:
        asyncio.run(self._main())

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._shutdown = asyncio.Event()
        self._sem = asyncio.Semaphore(self.max_concurrent)
        server = await asyncio.start_server(self._client, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        self._ready.set()
        async with server:
            await self._shutdown.wait()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ------------------------------------------------------------- routing
    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_http(reader)
            if parsed is None:
                return
            method, path, body = parsed
            self.counters["requests"] += 1
            if path == "/health" and method == "GET":
                writer.write(_response(200,
                                       _json_bytes(self.frontend.health())))
            elif path == "/metrics" and method == "GET":
                writer.write(_response(200, _json_bytes(self._metrics())))
            elif path == "/v1/chat/completions":
                if method != "POST":
                    writer.write(_response(
                        405, _error_body(405, "use POST")))
                else:
                    await self._chat(reader, writer, body)
            else:
                writer.write(_response(
                    404, _error_body(404, f"unknown path {path}")))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass
        except Exception as e:                        # noqa: BLE001
            try:
                writer.write(_response(500, _error_body(500, repr(e))))
                await writer.drain()
            except Exception:                         # noqa: BLE001
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:                         # noqa: BLE001
                pass

    async def _read_http(self, reader: asyncio.StreamReader
                         ) -> Optional[tuple[str, str, bytes]]:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin1").split()
        if len(parts) < 3:
            return None
        method, path = parts[0], parts[1]
        headers: dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or "0")
        body = await reader.readexactly(n) if n else b""
        return method, path, body

    def _metrics(self) -> dict[str, Any]:
        return {"gateway": dict(self.counters),
                "admission": {"max_concurrent": self.max_concurrent,
                              "max_queue": self.max_queue,
                              "waiting": self._waiting},
                "engine": self.frontend.stats}

    # ---------------------------------------------------------- completions
    async def _chat(self, reader: asyncio.StreamReader,
                    writer: asyncio.StreamWriter, body: bytes) -> None:
        try:
            payload = json.loads(body.decode("utf-8"))
            if not isinstance(payload, dict):
                raise APIError("payload must be a JSON object")
        except (ValueError, UnicodeDecodeError) as e:
            self.counters["rejected_400"] += 1
            writer.write(_response(400, _error_body(400, f"bad JSON: {e}")))
            return

        # bounded admission: beyond max_concurrent running and max_queue
        # waiting, shed load with 429 instead of queueing unboundedly
        if self._sem.locked() and self._waiting >= self.max_queue:
            self.counters["rejected_429"] += 1
            writer.write(_response(
                429, _error_body(429, "admission queue full")))
            return
        self._waiting += 1
        try:
            await self._sem.acquire()
        finally:
            self._waiting -= 1
        try:
            await self._chat_admitted(reader, writer, payload)
        finally:
            self._sem.release()

    async def _chat_admitted(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter,
                             payload: dict) -> None:
        try:
            req = parse_chat_request(self.frontend.cfg, payload)
            handle = self.frontend.submit(req)
        except (APIError, ValueError, TypeError, KeyError) as e:
            # schema errors (APIError from CompletionParams.validate /
            # parse) and engine admission errors (capacity) are all the
            # client's payload's fault
            self.counters["rejected_400"] += 1
            writer.write(_response(400, _error_body(400, str(e) or repr(e))))
            return
        if payload.get("stream"):
            await self._stream_response(reader, writer, handle)
        else:
            await self._unary_response(reader, writer, handle)

    def _collect(self, req_id: int) -> None:
        collect = getattr(self.frontend, "collect", None)
        if collect is not None:
            collect(req_id)

    # ------------------------------------------------------ unary responses
    def _result_worker(self, handle: Any) -> dict:
        """Detok-pool job: block on the engine result and shape the
        OpenAI response off the event loop."""
        out = handle.result(timeout=self.request_timeout)
        if out.error is not None:
            raise _EngineFailure(out.error)
        return build_chat_response(self.frontend.cfg, out)

    async def _unary_response(self, reader: asyncio.StreamReader,
                              writer: asyncio.StreamWriter,
                              handle: Any) -> None:
        req_id = handle.req.req_id
        fut = self._loop.run_in_executor(self._pool, self._result_worker,
                                         handle)
        watcher = asyncio.create_task(self._eof(reader))
        try:
            done, _ = await asyncio.wait(
                {fut, watcher}, return_when=asyncio.FIRST_COMPLETED)
            if fut not in done:
                # client hung up before the result: abort server-side
                self.counters["disconnects"] += 1
                self.frontend.abort(req_id, "client disconnected")
                await fut     # worker returns promptly (request FAILED)
                return
            resp = fut.result()
            writer.write(_response(200, _json_bytes(resp)))
            self.counters["completions"] += 1
        except RequestTimeout:
            self.counters["timeouts_408"] += 1
            self.frontend.abort(req_id, "request timed out at the gateway")
            writer.write(_response(
                408, _error_body(408, "request timed out")))
        except _EngineFailure as e:
            self.counters["failures_500"] += 1
            writer.write(_response(500, _error_body(500, str(e))))
        finally:
            watcher.cancel()
            self._collect(req_id)

    # -------------------------------------------------------- SSE streaming
    def _stream_worker(self, handle: Any, q: asyncio.Queue,
                       cancel: threading.Event) -> None:
        """Detok-pool job: iterate the engine's token stream, detokenize
        incrementally, and assemble SSE chunk bytes — all off the event
        loop AND off the scheduler thread. ``None`` terminates."""
        req = handle.req
        cfg = self.frontend.cfg
        detok = IncrementalDetokenizer()

        def put(item) -> None:
            try:
                self._loop.call_soon_threadsafe(q.put_nowait, item)
            except RuntimeError:      # loop closed mid-shutdown
                pass

        try:
            put(_sse(build_chat_chunk(cfg, req, role=True)))
            for tok in handle.stream(timeout=self.request_timeout):
                if cancel.is_set():
                    return
                put(_sse(build_chat_chunk(cfg, req, detok.feed(tok))))
            fr = req.finish_reason.value if req.finish_reason else "stop"
            put(_sse(build_chat_chunk(cfg, req, finish_reason=fr)))
            put(b"data: [DONE]\n\n")
        except RequestTimeout:
            put(_sse({"error": {"message": "request timed out",
                                "code": 408}}))
        except RuntimeError as e:
            if not cancel.is_set():
                put(_sse({"error": {"message": str(e), "code": 500}}))
        finally:
            put(None)

    async def _stream_response(self, reader: asyncio.StreamReader,
                               writer: asyncio.StreamWriter,
                               handle: Any) -> None:
        req_id = handle.req.req_id
        self.counters["streams"] += 1
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        q: asyncio.Queue = asyncio.Queue()
        cancel = threading.Event()
        fut = self._loop.run_in_executor(self._pool, self._stream_worker,
                                         handle, q, cancel)
        watcher = asyncio.create_task(self._eof_to_queue(reader, q))
        disconnected = False
        try:
            while True:
                item = await q.get()
                if item is None:
                    break
                if item is _DISCONNECT:
                    disconnected = True
                    break
                try:
                    writer.write(item)
                    await writer.drain()
                except (ConnectionResetError, BrokenPipeError, OSError):
                    disconnected = True
                    break
        finally:
            watcher.cancel()
            if disconnected:
                # client hung up mid-stream: abort releases the KV blocks
                # and ψ-channel state server-side; the worker notices the
                # cancel flag (or its stream failing) and exits
                cancel.set()
                self.counters["disconnects"] += 1
                self.frontend.abort(req_id, "client disconnected")
            await fut
            self._collect(req_id)

    # ------------------------------------------------------------- watchers
    async def _eof(self, reader: asyncio.StreamReader) -> None:
        """Resolve when the client closes its end of the connection."""
        try:
            while await reader.read(1024):
                pass
        except Exception:                             # noqa: BLE001
            pass

    async def _eof_to_queue(self, reader: asyncio.StreamReader,
                            q: asyncio.Queue) -> None:
        await self._eof(reader)
        q.put_nowait(_DISCONNECT)
