"""Disaggregation-aware load balancer over N engine backends.

The gateway's front door for multi-engine deployments: register several
backends (``EPDEngine`` or ``ClusterEngine`` — anything speaking the
``EngineBase`` surface), health-check them with latency EWMAs, and route
each request by **role** and **pressure**:

  * role: a multimodal request can only go to a backend with an
    E-capable instance (``current_roles``) — the modality-aware dispatch
    ElasticMM (PAPERS.md) builds its elastic groups around;
  * pressure: among eligible backends, pick the lowest composite score of
    queue depth, LB-tracked in-flight count, KV pool occupancy
    (1 - free-block fraction, weighted — a nearly-full pool means
    imminent preemptions), and the health-probe latency EWMA (a limping
    backend sheds load before it fails outright).

Failure handling: ``max_failures`` consecutive failed/not-ok health
probes mark a backend unhealthy; its requests that have not produced any
token yet (queued / encoding / prefilling — "not-yet-admitted" work) are
aborted there and **resubmitted** to a healthy backend as pristine
clones, transparently to the caller — an ``LBTicket``'s ``result()`` /
``stream()`` follow the request to its new home (zero tokens were
delivered, so greedy replay is invisible). Requests already decoding are
aborted and surface as failures (their stream position cannot be
replayed without token loss guarantees). ``remove_backend`` drains the
same way.

Everything is stdlib + the existing engine API: the LB itself exposes the
same duck-typed frontend surface the gateway consumes (``cfg``,
``submit``, ``abort``, ``stats``, ``health``), so ``GatewayServer`` can
front one engine or a balanced fleet without caring which.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Iterator, Optional

import numpy as np

from repro.serving.types import RequestTimeout, ServeRequest

__all__ = ["Backend", "LBTicket", "LoadBalancer"]

_FAILOVER_POLL = 0.1          # ticket wait slice while following a failover


def clone_request(req: ServeRequest) -> ServeRequest:
    """Pristine copy for failover resubmission: same req_id (registries
    are per-engine), same prompt/sampling, fresh lifecycle state."""
    return ServeRequest(
        req_id=req.req_id, prompt=req.prompt, mm_embeds=req.mm_embeds,
        mm_positions=req.mm_positions, max_new_tokens=req.max_new_tokens,
        sampling=req.sampling)


class Backend:
    """One registered engine + the LB's view of its health and load."""

    def __init__(self, name: str, engine: Any):
        self.name = name
        self.engine = engine
        self.healthy = True
        self.draining = False         # no new routes; in-flight finishes
        self.ewma_ms: Optional[float] = None
        self.consecutive_failures = 0
        self.consecutive_successes = 0
        self.probes = 0

    def serves_encode(self) -> bool:
        return any("E" in r for r in self.engine.current_roles())

    def observe_probe(self, latency_ms: float, ok: bool,
                      alpha: float) -> None:
        self.probes += 1
        if ok:
            # failed probes don't update the EWMA: a probe that errored or
            # timed out measures the failure path, not service latency, and
            # would poison the routing score for long after recovery
            self.ewma_ms = (latency_ms if self.ewma_ms is None
                            else alpha * latency_ms
                            + (1 - alpha) * self.ewma_ms)
            self.consecutive_failures = 0
            self.consecutive_successes += 1
        else:
            self.consecutive_failures += 1
            self.consecutive_successes = 0

    def snapshot(self) -> dict[str, Any]:
        free, total = self.engine.kv_block_counts()
        return {"name": self.name, "healthy": self.healthy,
                "draining": self.draining,
                "queue_depth": self.engine.queue_depth(),
                "kv_free_blocks": free, "kv_total_blocks": total,
                "ewma_ms": self.ewma_ms,
                "roles": self.engine.current_roles()}


class LBTicket:
    """The caller's handle to a balanced request. Mirrors
    ``RequestHandle.result()/stream()`` but follows the request across a
    failover resubmission (the underlying engine handle is swapped and a
    generation counter tells waiters to re-wait on the new one)."""

    def __init__(self, lb: "LoadBalancer", backend: Backend, handle: Any):
        self.lb = lb
        self.backend = backend
        self.handle = handle
        self.generation = 0
        self._lock = threading.Lock()

    @property
    def req_id(self) -> int:
        return self.handle.req.req_id

    @property
    def req(self) -> ServeRequest:
        return self.handle.req

    def _current(self) -> tuple[int, Any]:
        with self._lock:
            return self.generation, self.handle

    def _reassign(self, backend: Backend, handle: Any) -> None:
        with self._lock:
            self.backend = backend
            self.handle = handle
            self.generation += 1

    def result(self, timeout: float = 300.0) -> ServeRequest:
        deadline = time.time() + timeout
        while True:
            gen, handle = self._current()
            try:
                # the deadline can race past between the loop check and
                # here — clamp so handle.result never sees a negative wait
                wait = max(0.0, min(_FAILOVER_POLL, deadline - time.time()))
                out = handle.result(timeout=wait)
            except RequestTimeout:
                if time.time() >= deadline:
                    raise RequestTimeout(self.req_id, timeout) from None
                continue
            if self._current()[0] != gen and out.finished and out.error:
                continue              # failed over mid-wait: follow it
            return out

    def stream(self, timeout: float = 300.0) -> Iterator[int]:
        deadline = time.time() + timeout
        while True:
            gen, handle = self._current()
            yielded = 0
            try:
                for tok in handle.stream(timeout=deadline - time.time()):
                    yield tok
                    yielded += 1
                return
            except RuntimeError:
                # the backend-side request failed; if the LB moved the
                # request (zero tokens were ever delivered — failover
                # only resubmits token-less requests) restart on the new
                # handle, else surface the failure
                if yielded == 0:
                    spin = time.time() + 2 * _FAILOVER_POLL
                    while self._current()[0] == gen and time.time() < spin:
                        time.sleep(0.01)   # failover may still be swapping
                    if self._current()[0] != gen:
                        continue
                raise


class LoadBalancer:
    """Role/pressure router + health checker over registered backends."""

    def __init__(self, *, health_interval: float = 0.25,
                 ewma_alpha: float = 0.3, max_failures: int = 3,
                 kv_pressure_weight: float = 4.0):
        self.backends: dict[str, Backend] = {}
        self.tickets: dict[int, LBTicket] = {}
        self.health_interval = health_interval
        self.ewma_alpha = ewma_alpha
        self.max_failures = max_failures
        self.kv_pressure_weight = kv_pressure_weight
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.counters = {"routed": 0, "failovers": 0, "failover_failures": 0,
                         "health_probes": 0, "backends_marked_unhealthy": 0}

    # ------------------------------------------------------------ registry
    def add_backend(self, name: str, engine: Any) -> Backend:
        with self._lock:
            if name in self.backends:
                raise ValueError(f"backend {name!r} already registered")
            b = Backend(name, engine)
            self.backends[name] = b
            return b

    def remove_backend(self, name: str) -> None:
        """Drain + deregister: no new routes, token-less requests fail
        over to the remaining backends, decoding requests finish in
        place (their tickets keep pointing at the removed engine)."""
        with self._lock:
            b = self.backends.get(name)
            if b is None:
                return
            b.draining = True
        self._failover(b, reason=f"backend {name} removed")
        with self._lock:
            self.backends.pop(name, None)

    # ------------------------------------------------------------- routing
    @property
    def cfg(self):
        """Model config of the fleet (gateway parses requests against it;
        all backends are assumed to serve the same model)."""
        with self._lock:
            for b in self.backends.values():
                return b.engine.cfg
        raise RuntimeError("no backends registered")

    def _eligible(self, req: ServeRequest) -> list[Backend]:
        needs_e = (req.mm_embeds is not None
                   and np.asarray(req.mm_embeds).shape[0] > 0)
        with self._lock:
            cands = [b for b in self.backends.values()
                     if b.healthy and not b.draining]
        if needs_e:
            cands = [b for b in cands if b.serves_encode()]
        return cands

    def score(self, b: Backend) -> float:
        """Composite pressure: queued work + pool occupancy + probe EWMA.
        Lower is better; ties broken by registration order."""
        free, total = b.engine.kv_block_counts()
        free_frac = (free / total) if total else 1.0
        with self._lock:
            inflight = sum(1 for t in self.tickets.values()
                           if t.backend is b and not t.req.finished)
        return (b.engine.queue_depth() + inflight
                + self.kv_pressure_weight * (1.0 - free_frac)
                + (b.ewma_ms or 0.0) / 10.0)

    def submit(self, req: ServeRequest) -> LBTicket:
        cands = self._eligible(req)
        if not cands:
            raise RuntimeError(
                "no eligible backend (none healthy, or no E-capable "
                "backend for a multimodal request)")
        best = min(cands, key=self.score)
        handle = best.engine.submit(req)
        ticket = LBTicket(self, best, handle)
        with self._lock:
            self.tickets[req.req_id] = ticket
            self.counters["routed"] += 1
        return ticket

    def abort(self, req_id: int, reason: str = "aborted by client") -> bool:
        with self._lock:
            ticket = self.tickets.get(req_id)
        if ticket is None:
            return False
        return ticket.backend.engine.abort(req_id, reason)

    def collect(self, req_id: int) -> None:
        """Drop a finished request's ticket and collect it on its backend
        (gateway calls this after the response is written, so neither
        registry can grow unbounded)."""
        with self._lock:
            ticket = self.tickets.pop(req_id, None)
        if ticket is not None:
            ticket.backend.engine.collect(req_id)

    # ------------------------------------------------------------ failover
    def _failover(self, dead: Backend, reason: str) -> None:
        """Re-home ``dead``'s token-less requests; abort the rest.

        Resubmission happens BEFORE the abort on the dead backend: the
        ticket's generation bumps first, so a waiter woken by the abort
        always finds the new handle and never surfaces the transient
        failure. Requests that already delivered tokens cannot be
        re-homed without replaying part of the stream, so they fail."""
        with self._lock:
            victims = [t for t in self.tickets.values()
                       if t.backend is dead and not t.req.finished]
        for t in victims:
            req = t.req
            if len(req.tokens) == 0 and not req.finished:
                clone = clone_request(req)
                cands = self._eligible(clone)
                if cands:
                    try:
                        best = min(cands, key=self.score)
                        t._reassign(best, best.engine.submit(clone))
                        with self._lock:
                            self.counters["failovers"] += 1
                    except Exception:                 # noqa: BLE001
                        with self._lock:
                            self.counters["failover_failures"] += 1
                else:
                    with self._lock:
                        self.counters["failover_failures"] += 1
            try:
                dead.engine.abort(req.req_id, reason)
            except Exception:                         # noqa: BLE001
                # a dead engine is allowed to be *really* dead — a raising
                # abort must not kill the health loop (lb-health thread)
                # mid-sweep and leave the rest of the victims stranded
                with self._lock:
                    self.counters["failover_failures"] += 1

    # -------------------------------------------------------- health loop
    def health_check_once(self) -> None:
        """One probe round (public so tests drive it without the timer)."""
        with self._lock:
            backends = list(self.backends.values())
        for b in backends:
            if b.draining:
                continue
            t0 = time.perf_counter()
            try:
                h = b.engine.health()
                ok = bool(h.get("ok", False))
            except Exception:                         # noqa: BLE001
                ok = False
            ms = (time.perf_counter() - t0) * 1e3
            b.observe_probe(ms, ok, self.ewma_alpha)
            with self._lock:
                self.counters["health_probes"] += 1
            if (b.healthy and not ok
                    and b.consecutive_failures >= self.max_failures):
                b.healthy = False
                with self._lock:
                    self.counters["backends_marked_unhealthy"] += 1
                self._failover(b, reason=f"backend {b.name} unhealthy")
            elif (not b.healthy and ok
                    and b.consecutive_successes >= self.max_failures):
                # symmetric hysteresis: one ok probe from a flapping
                # backend must not re-admit it (and re-trigger a failover
                # storm on the next blip) — demand the same streak length
                # that marked it unhealthy
                b.healthy = True

    def _health_loop(self) -> None:
        while not self._stop.wait(self.health_interval):
            self.health_check_once()

    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._health_loop,
                                            daemon=True, name="lb-health")
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # ------------------------------------------------------------- queries
    def health(self) -> dict[str, Any]:
        with self._lock:
            backends = list(self.backends.values())
        snaps = [b.snapshot() for b in backends]
        return {"ok": any(s["healthy"] for s in snaps),
                "backends": snaps,
                "lb": dict(self.counters)}

    @property
    def stats(self) -> dict[str, Any]:
        """Aggregated engine counters across backends + LB counters."""
        agg: dict[str, Any] = {}
        with self._lock:
            backends = list(self.backends.values())
        for b in backends:
            for k, v in b.engine.stats.items():
                if isinstance(v, (int, float)):
                    agg[k] = agg.get(k, 0) + v
        agg["lb"] = dict(self.counters)
        return agg
