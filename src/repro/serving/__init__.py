from repro.serving.engine import EPDEngine
from repro.serving.scheduler import Scheduler
from repro.serving.transfer import (MMTokenCache, PrefillProgress, PsiEP,
                                    PsiPD)
from repro.serving.types import (EngineConfig, FinishReason, RequestHandle,
                                 RequestState, SamplingParams, ServeRequest)

__all__ = ["EPDEngine", "EngineConfig", "ServeRequest", "SamplingParams",
           "RequestState", "FinishReason", "RequestHandle", "MMTokenCache",
           "PsiEP", "PsiPD", "PrefillProgress", "Scheduler"]
