from repro.serving.cluster import ClusterEngine, InstanceWorker
from repro.serving.engine import EngineBase, EPDEngine
from repro.serving.gateway import GatewayServer
from repro.serving.lb import Backend, LBTicket, LoadBalancer
from repro.serving.runner import ChunkWork, ModelRunner
from repro.serving.scheduler import Scheduler
from repro.serving.transfer import (MigratedPrefill, MMTokenCache,
                                    PrefillProgress, PsiEP, PsiPD)
from repro.serving.types import (ClusterConfig, EngineConfig, FinishReason,
                                 RequestHandle, RequestState, RequestTimeout,
                                 SamplingParams, ServeRequest)

__all__ = ["EPDEngine", "EngineBase", "ClusterEngine", "InstanceWorker",
           "EngineConfig", "ClusterConfig", "ServeRequest", "SamplingParams",
           "RequestState", "FinishReason", "RequestHandle", "RequestTimeout",
           "MMTokenCache", "PsiEP", "PsiPD", "PrefillProgress",
           "MigratedPrefill", "Scheduler", "ModelRunner", "ChunkWork",
           "GatewayServer", "LoadBalancer", "LBTicket", "Backend"]
