from repro.serving.engine import EPDEngine, EngineConfig, ServeRequest

__all__ = ["EPDEngine", "EngineConfig", "ServeRequest"]
