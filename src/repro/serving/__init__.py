from repro.serving.cluster import ClusterEngine, InstanceWorker
from repro.serving.engine import EngineBase, EPDEngine
from repro.serving.runner import ChunkWork, ModelRunner
from repro.serving.scheduler import Scheduler
from repro.serving.transfer import (MigratedPrefill, MMTokenCache,
                                    PrefillProgress, PsiEP, PsiPD)
from repro.serving.types import (ClusterConfig, EngineConfig, FinishReason,
                                 RequestHandle, RequestState, SamplingParams,
                                 ServeRequest)

__all__ = ["EPDEngine", "EngineBase", "ClusterEngine", "InstanceWorker",
           "EngineConfig", "ClusterConfig", "ServeRequest", "SamplingParams",
           "RequestState", "FinishReason", "RequestHandle", "MMTokenCache",
           "PsiEP", "PsiPD", "PrefillProgress", "MigratedPrefill",
           "Scheduler", "ModelRunner", "ChunkWork"]
