"""Real-execution EPD serving engine.

Runs the actual E / P / D stage functions (jitted JAX) on live threads with
queues between stages — the same architecture the simulator models, but
executing real tensors. On a TPU cluster each stage thread drives its own
submesh; on this CPU container it serves reduced-config models end-to-end
(examples/epd_serve.py).

Pipeline (paper §3.1):
  E thread:  mm_embeds --encode--> mm tokens  (IRP: patch-shards in parallel)
  EP queue:  ψ_EP — tokens handed to P (device-to-device put on real HW)
  P thread:  prefill -> first token + KV written into the shared paged pool
  PD queue:  ψ_PD — a block-table handoff (paged) or cache copy (dense)
  D thread:  batched decode over fixed slots until EOS/length

Decode stage (paper's 22x-batches / 2.2x-KV headline): all active requests
share one paged KV pool managed by ``KVBlockManager``; every iteration is a
SINGLE jitted ``paged_decode_step`` over ``decode_batch`` fixed slots —
inactive slots are padded (they write to a reserved trash block), so the
call never recompiles as requests come and go. The seed's per-request dense
loop is kept as ``mode="dense"`` for comparison benchmarks.
"""
from __future__ import annotations

import math
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.block_manager import KVBlockManager, OutOfBlocks
from repro.models import build_model

PAGED_FAMILIES = ("dense", "moe", "vlm")


@dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray                       # (S,) int32
    mm_embeds: Optional[np.ndarray] = None   # (M, d_frontend)
    mm_positions: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    # timestamps
    t_submit: float = 0.0
    t_encoded: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    tokens: list[int] = field(default_factory=list)
    n_preemptions: int = 0

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        n = len(self.tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


@dataclass
class EngineConfig:
    n_encode_workers: int = 2          # IRP degree
    max_new_tokens: int = 16
    decode_batch: int = 8              # fixed decode slots (paged mode)
    cache_headroom: int = 64           # dense mode only
    # paged decode stage
    mode: str = "paged"                # "paged" | "dense"
    kv_blocks: int = 256               # shared pool size (blocks)
    kv_block_size: int = 16            # tokens per block
    max_seq_len: int = 256             # block-table width cap per sequence


class EPDEngine:
    """Threaded EPD pipeline over a real model."""

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.ecfg = engine
        self.paged = (engine.mode == "paged"
                      and cfg.family in PAGED_FAMILIES
                      and not cfg.sliding_window)

        self._eq: queue.Queue = queue.Queue()    # encode jobs
        self._pq: queue.Queue = queue.Queue()    # prefill jobs (post ψ_EP)
        self._dq: queue.Queue = queue.Queue()    # decode jobs  (post ψ_PD)
        self._done: dict[int, ServeRequest] = {}
        self._done_cv = threading.Condition()
        self._shards: dict[int, list] = {}
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self.stats: dict[str, Any] = {
            "decode_tokens": 0, "decode_time": 0.0, "decode_steps": 0,
            "peak_cache_bytes": 0, "preemptions": 0}

        # jitted stage fns (prefill variants retrace per (S, max_len) pair)
        self._encode = jax.jit(self.model.encode) if self.model.encode else None
        self._prefill = jax.jit(
            lambda p, b, ml: self.model.prefill(p, batch=b, max_len=ml),
            static_argnums=(2,))
        self._prefill_merged = jax.jit(
            lambda p, b, ml: _prefill_premerged(self.model, self.cfg,
                                                p, b, ml),
            static_argnums=(2,))
        self._decode = jax.jit(
            lambda p, b: self.model.decode_step(p, batch=b))
        self._live_cache_bytes = 0               # dense-mode KV accounting
        self._stats_lock = threading.Lock()      # P and D both update peaks

        if self.paged:
            bs = engine.kv_block_size
            self.kv_mgr = KVBlockManager(engine.kv_blocks, bs)
            self._kv_lock = threading.Lock()     # guards kv_mgr
            self._pool_lock = threading.Lock()   # guards the pool arrays
            self._max_blocks = math.ceil(engine.max_seq_len / bs)
            self._trash = engine.kv_blocks       # reserved block id N-1
            self._k_pool, self._v_pool = self.model.init_kv_pool(
                engine.kv_blocks, bs)
            # bytes of one (k + v) block pair, for peak-memory accounting
            self._block_bytes = 2 * (cfg.n_layers * bs * cfg.n_kv_heads
                                     * cfg.head_dim
                                     * self._k_pool.dtype.itemsize)
            # Pallas kernel only off interpret-mode on TPU; elsewhere the
            # jnp oracle keeps the batched step fast (same contract).
            force_ref = jax.default_backend() != "tpu"
            # donate the pool buffers so XLA updates them in place instead
            # of copying the whole pool every step (CPU ignores donation
            # and warns, so only donate on accelerators)
            on_cpu = jax.default_backend() == "cpu"
            self._paged_decode = jax.jit(
                lambda p, b: self.model.paged_decode_step(
                    p, batch=b, force_ref=force_ref),
                donate_argnums=() if on_cpu else (1,))
            # prefill split: the forward pass runs WITHOUT the pool lock
            # (it doesn't read the pool); only the block scatter holds it,
            # so prefill latency never stalls the batched decode loop
            from repro.models import dense
            self._prefill_core = jax.jit(
                lambda p, b: dense.prefill_core(p, self.cfg, b))
            self._pool_write = jax.jit(
                dense.pool_write_prefill,
                donate_argnums=() if on_cpu else (0, 1))

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        for i in range(max(1, self.ecfg.n_encode_workers)):
            t = threading.Thread(target=self._encode_loop, daemon=True,
                                 name=f"E{i}")
            t.start()
            self._threads.append(t)
        decode = self._decode_loop_paged if self.paged else self._decode_loop
        for name, loop in (("P0", self._prefill_loop), ("D0", decode)):
            t = threading.Thread(target=loop, daemon=True, name=name)
            t.start()
            self._threads.append(t)

    def stop(self, timeout: float = 5.0) -> None:
        """Signal all stage threads and join them (deterministic shutdown)."""
        self._stop.set()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.time()))
        self._threads = [t for t in self._threads if t.is_alive()]

    # -------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> None:
        if self.paged:
            # prefill allocates S+1 (first decode write); lifetime peak is
            # the larger of that and the full generated length
            total = max(len(req.prompt) + req.max_new_tokens,
                        len(req.prompt) + 1)
            cap = min(self.ecfg.max_seq_len,
                      self.ecfg.kv_blocks * self.ecfg.kv_block_size)
            if total > cap:
                raise ValueError(
                    f"request {req.req_id}: {total} tokens exceeds "
                    f"capacity {cap} (max_seq_len={self.ecfg.max_seq_len}, "
                    f"pool={self.ecfg.kv_blocks}x"
                    f"{self.ecfg.kv_block_size})")
        req.t_submit = time.perf_counter()
        has_mm = (req.mm_embeds is not None and self._encode is not None
                  and req.mm_embeds.shape[0] > 0)
        if has_mm:
            # Intra-Request Parallelism: shard the PATCH GROUPS across E
            # workers. Boundaries align to tokens_per_item so each shard is
            # a whole number of independently-encoded patches (lossless
            # merge, paper §3.2.2).
            M = req.mm_embeds.shape[0]
            tpi = (self.cfg.modality.tokens_per_item
                   if self.cfg.modality else M)
            n_groups = -(-M // tpi)
            n = max(1, min(self.ecfg.n_encode_workers, n_groups))
            group_ids = np.array_split(np.arange(n_groups), n)
            self._shards[req.req_id] = [None] * n
            for sid, gids in enumerate(group_ids):
                idx = np.concatenate([
                    np.arange(g * tpi, min((g + 1) * tpi, M)) for g in gids])
                self._eq.put((req, sid, n, idx))
        else:
            req.t_encoded = time.perf_counter()
            self._pq.put((req, None))

    def result(self, req_id: int, timeout: float = 300.0) -> ServeRequest:
        deadline = time.time() + timeout
        with self._done_cv:
            while req_id not in self._done:
                remaining = deadline - time.time()
                if remaining <= 0:
                    raise TimeoutError(f"request {req_id}")
                self._done_cv.wait(remaining)
            return self._done.pop(req_id)

    def _finish(self, req: ServeRequest) -> None:
        req.t_done = time.perf_counter()
        with self._done_cv:
            self._done[req.req_id] = req
            self._done_cv.notify_all()

    # --------------------------------------------------------------- loops
    def _encode_loop(self) -> None:
        while not self._stop.is_set():
            try:
                req, sid, n, idx = self._eq.get(timeout=0.05)
            except queue.Empty:
                continue
            shard = jnp.asarray(req.mm_embeds[idx])[None]       # (1, m, d)
            tokens = np.asarray(self._encode(self.params, shard)[0])
            shards = self._shards[req.req_id]
            shards[sid] = (idx, tokens)
            if all(s is not None for s in shards):
                # ψ_EP: align + merge shard tokens (paper §3.2.2)
                M = req.mm_embeds.shape[0]
                d = tokens.shape[-1]
                merged = np.zeros((M, d), tokens.dtype)
                for s_idx, s_tok in shards:
                    merged[s_idx] = s_tok
                del self._shards[req.req_id]
                req.t_encoded = time.perf_counter()
                self._pq.put((req, merged))

    def _prefill_loop(self) -> None:
        while not self._stop.is_set():
            try:
                req, mm_tokens = self._pq.get(timeout=0.05)
            except queue.Empty:
                continue
            if self.paged:
                # head-of-line retry on a momentarily full pool: holding
                # the request (instead of requeueing it behind later
                # arrivals) keeps admission in FIFO order, so a long
                # request cannot be starved by a stream of short ones
                while (not self._prefill_paged(req, mm_tokens)
                       and not self._stop.is_set()):
                    time.sleep(0.01)
                continue
            batch = {"tokens": jnp.asarray(req.prompt)[None]}
            if mm_tokens is not None:
                # tokens already encoded at E; hand P the merged mm tokens
                batch["mm_embeds"] = None
            if self.cfg.family == "audio":
                batch["enc_frames"] = jnp.asarray(req.mm_embeds)[None]
            logits, cache = self._prefill_with_mm(batch, mm_tokens, req)
            tok = int(np.argmax(np.asarray(logits[0])))
            req.tokens.append(tok)
            req.t_first_token = time.perf_counter()
            # live-KV accounting: a dense cache exists from prefill to
            # completion (it pads every request to S + max_new + headroom)
            with self._stats_lock:
                self._live_cache_bytes += _cache_nbytes(cache)
                self.stats["peak_cache_bytes"] = max(
                    self.stats["peak_cache_bytes"], self._live_cache_bytes)
            # ψ_PD: cache moves to the decode stage
            self._dq.put((req, tok, cache))

    def _prefill_with_mm(self, batch, mm_tokens, req):
        S = int(batch["tokens"].shape[1])
        max_len = S + req.max_new_tokens + self.ecfg.cache_headroom
        if mm_tokens is not None:
            x_batch = dict(batch)
            x_batch.pop("mm_embeds", None)
            x_batch["mm_tokens"] = jnp.asarray(mm_tokens)[None]
            x_batch["mm_positions"] = jnp.asarray(req.mm_positions)[None]
            return self._prefill_merged(self.params, x_batch, max_len)
        batch = {k: v for k, v in batch.items() if v is not None}
        return self._prefill(self.params, batch, max_len)

    # ------------------------------------------------------ paged prefill
    def _prefill_paged(self, req: ServeRequest, mm_tokens) -> bool:
        """Prefill straight into pool blocks. Returns False if the pool
        cannot hold the prompt right now (caller requeues)."""
        S = len(req.prompt)
        with self._kv_lock:
            # +1 headroom so the first decode write never needs append
            if not self.kv_mgr.can_allocate(S + 1):
                return False
            blocks = self.kv_mgr.allocate(req.req_id, S + 1)
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        if mm_tokens is not None:
            batch["mm_tokens"] = jnp.asarray(mm_tokens)[None]
            batch["mm_positions"] = jnp.asarray(req.mm_positions)[None]
        with self._kv_lock, self._stats_lock:
            self.stats["peak_cache_bytes"] = max(
                self.stats["peak_cache_bytes"],
                self.kv_mgr.used_blocks * self._block_bytes)
        ids = jnp.asarray(blocks, jnp.int32)
        logits, ks, vs = self._prefill_core(self.params, batch)
        with self._pool_lock:
            self._k_pool, self._v_pool = self._pool_write(
                self._k_pool, self._v_pool, ks, vs, ids)
        tok = int(np.argmax(np.asarray(logits[0])))
        req.tokens.append(tok)
        req.t_first_token = time.perf_counter()
        # ψ_PD: block-table handoff — no cache copy. mm_tokens ride along
        # so the decode stage can requeue the request on preemption.
        self._dq.put((req, tok, S, mm_tokens))
        return True

    # ------------------------------------------------------- dense decode
    def _decode_loop(self) -> None:
        # seed path: continuous batching over independent (cache, token)
        # pairs, one jitted batch-1 call per request per iteration. Kept as
        # the comparison baseline for the paged-batched decode stage.
        active: list[tuple[ServeRequest, int, Any]] = []
        while not self._stop.is_set():
            while len(active) < self.ecfg.decode_batch:
                try:
                    active.append(self._dq.get_nowait())
                except queue.Empty:
                    break
            if not active:
                time.sleep(0.005)
                continue
            t0 = time.perf_counter()
            nxt = []
            stepped = 0
            for req, tok, cache in active:
                if len(req.tokens) >= req.max_new_tokens:
                    with self._stats_lock:
                        self._live_cache_bytes -= _cache_nbytes(cache)
                    self._finish(req)
                    continue
                logits, cache = self._decode(
                    self.params,
                    {"token": jnp.asarray([tok], jnp.int32), "cache": cache})
                tok = int(np.argmax(np.asarray(logits[0])))
                req.tokens.append(tok)
                stepped += 1
                nxt.append((req, tok, cache))
            if stepped:
                self.stats["decode_time"] += time.perf_counter() - t0
                self.stats["decode_tokens"] += stepped
                self.stats["decode_steps"] += 1
            active = nxt

    # ------------------------------------------------------- paged decode
    def _decode_loop_paged(self) -> None:
        """Fixed decode slots over the shared paged pool: admit from _dq
        into free slots, grow allocations via KVBlockManager.append, ONE
        jitted batched step per iteration regardless of the active count."""
        n_slots = self.ecfg.decode_batch
        slots: list[Optional[dict]] = [None] * n_slots
        tokens = np.zeros((n_slots,), np.int32)
        positions = np.zeros((n_slots,), np.int32)
        tables = np.full((n_slots, self._max_blocks), self._trash, np.int32)

        while not self._stop.is_set():
            # admit new requests into free slots (ψ_PD handoff: block table
            # row comes straight from the manager, no cache copy)
            for i in range(n_slots):
                if slots[i] is not None:
                    continue
                try:
                    req, tok, n_cached, mm_tokens = self._dq.get_nowait()
                except queue.Empty:
                    break
                with self._kv_lock:
                    blocks = self.kv_mgr.owner_blocks(req.req_id)
                slots[i] = {"req": req, "mm_tokens": mm_tokens}
                tokens[i] = tok
                positions[i] = n_cached
                tables[i, :] = self._trash
                tables[i, :len(blocks)] = blocks

            # retire finished requests before stepping
            for i, s in enumerate(slots):
                if s is None:
                    continue
                req = s["req"]
                if len(req.tokens) >= req.max_new_tokens:
                    with self._kv_lock:
                        self.kv_mgr.free(req.req_id)
                    self._finish(req)
                    slots[i] = None
                    tables[i, :] = self._trash

            active = np.array([s is not None for s in slots])
            if not active.any():
                time.sleep(0.002)
                continue

            # grow allocations for this step's write; preempt on pressure
            for i, s in enumerate(slots):
                if s is None:
                    continue
                req = s["req"]
                with self._kv_lock:
                    try:
                        new = self.kv_mgr.append(req.req_id, 1,
                                                 int(positions[i]))
                    except OutOfBlocks:
                        owned = len(self.kv_mgr.owner_blocks(req.req_id))
                        if self.kv_mgr.used_blocks <= owned:
                            raise   # pool cannot hold even one request
                        self._preempt(i, slots, tables)
                        active[i] = False
                        continue
                if new:
                    have = int((tables[i] != self._trash).sum())
                    tables[i, have:have + len(new)] = new

            if not active.any():
                continue
            with self._kv_lock, self._stats_lock:
                self.stats["peak_cache_bytes"] = max(
                    self.stats["peak_cache_bytes"],
                    self.kv_mgr.used_blocks * self._block_bytes)

            # THE decode step: one jitted call for the whole slot batch
            t0 = time.perf_counter()
            batch = {"tokens": jnp.asarray(tokens),
                     "positions": jnp.asarray(positions),
                     "active": jnp.asarray(active),
                     "block_tables": jnp.asarray(tables)}
            with self._pool_lock:
                batch["k_pool"], batch["v_pool"] = self._k_pool, self._v_pool
                _, nxt_tok, self._k_pool, self._v_pool = self._paged_decode(
                    self.params, batch)
            nxt = np.asarray(nxt_tok)
            self.stats["decode_time"] += time.perf_counter() - t0
            self.stats["decode_tokens"] += int(active.sum())
            self.stats["decode_steps"] += 1

            for i, s in enumerate(slots):
                if s is None or not active[i]:
                    continue
                s["req"].tokens.append(int(nxt[i]))
                tokens[i] = nxt[i]
                positions[i] += 1

    def _preempt(self, i: int, slots: list, tables: np.ndarray) -> None:
        """OutOfBlocks under decode pressure: free this slot's blocks and
        requeue the request through P (greedy decode is deterministic, so
        the re-run reproduces the same prefix)."""
        s = slots[i]
        req = s["req"]
        self.kv_mgr.free(req.req_id)      # caller holds _kv_lock
        req.tokens = []
        req.n_preemptions += 1
        self.stats["preemptions"] += 1
        slots[i] = None
        tables[i, :] = self._trash
        self._pq.put((req, s["mm_tokens"]))


def _cache_nbytes(cache) -> int:
    return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(cache)))


def _prefill_premerged(model, cfg: ArchConfig, params, batch, max_len):
    """Prefill that takes ALREADY-ENCODED mm tokens (EPD path: E ran
    elsewhere), materializing a padded dense cache."""
    from repro.models import dense
    B, S = batch["tokens"].shape
    logits, ks, vs = dense.prefill_core(params, cfg, batch)
    if max_len > S:
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache
