"""Real-execution EPD serving engine.

Runs the actual E / P / D stage functions (jitted JAX) on live threads with
queues between stages — the same architecture the simulator models, but
executing real tensors. On a TPU cluster each stage thread drives its own
submesh; on this CPU container it serves reduced-config models end-to-end
(examples/epd_serve.py).

Pipeline (paper §3.1):
  E thread:  mm_embeds --encode--> mm tokens  (IRP: patch-shards in parallel)
  EP queue:  ψ_EP — tokens handed to P (device-to-device put on real HW)
  P thread:  prefill -> first token + KV cache
  PD queue:  ψ_PD — cache handed to D
  D thread:  continuous-batching decode until EOS/length
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model


@dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray                       # (S,) int32
    mm_embeds: Optional[np.ndarray] = None   # (M, d_frontend)
    mm_positions: Optional[np.ndarray] = None
    max_new_tokens: int = 16
    # timestamps
    t_submit: float = 0.0
    t_encoded: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    tokens: list[int] = field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        n = len(self.tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


@dataclass
class EngineConfig:
    n_encode_workers: int = 2          # IRP degree
    max_new_tokens: int = 16
    decode_batch: int = 8
    cache_headroom: int = 64


class EPDEngine:
    """Threaded EPD pipeline over a real model."""

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.ecfg = engine

        self._eq: queue.Queue = queue.Queue()    # encode jobs
        self._pq: queue.Queue = queue.Queue()    # prefill jobs (post ψ_EP)
        self._dq: queue.Queue = queue.Queue()    # decode jobs  (post ψ_PD)
        self._done: dict[int, ServeRequest] = {}
        self._done_lock = threading.Lock()
        self._shards: dict[int, list] = {}
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

        # jitted stage fns
        self._encode = jax.jit(self.model.encode) if self.model.encode else None
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(
                p, batch=b, max_len=None))
        self._decode = jax.jit(
            lambda p, b: self.model.decode_step(p, batch=b))

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        for i in range(max(1, self.ecfg.n_encode_workers)):
            t = threading.Thread(target=self._encode_loop, daemon=True,
                                 name=f"E{i}")
            t.start()
            self._threads.append(t)
        for name, loop in (("P0", self._prefill_loop), ("D0", self._decode_loop)):
            t = threading.Thread(target=loop, daemon=True, name=name)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()

    # -------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> None:
        req.t_submit = time.perf_counter()
        has_mm = (req.mm_embeds is not None and self._encode is not None
                  and req.mm_embeds.shape[0] > 0)
        if has_mm:
            # Intra-Request Parallelism: shard the PATCH GROUPS across E
            # workers. Boundaries align to tokens_per_item so each shard is
            # a whole number of independently-encoded patches (lossless
            # merge, paper §3.2.2).
            M = req.mm_embeds.shape[0]
            tpi = (self.cfg.modality.tokens_per_item
                   if self.cfg.modality else M)
            n_groups = -(-M // tpi)
            n = max(1, min(self.ecfg.n_encode_workers, n_groups))
            group_ids = np.array_split(np.arange(n_groups), n)
            self._shards[req.req_id] = [None] * n
            for sid, gids in enumerate(group_ids):
                idx = np.concatenate([
                    np.arange(g * tpi, min((g + 1) * tpi, M)) for g in gids])
                self._eq.put((req, sid, n, idx))
        else:
            req.t_encoded = time.perf_counter()
            self._pq.put((req, None))

    def result(self, req_id: int, timeout: float = 300.0) -> ServeRequest:
        t0 = time.time()
        while time.time() - t0 < timeout:
            with self._done_lock:
                if req_id in self._done:
                    return self._done.pop(req_id)
            time.sleep(0.005)
        raise TimeoutError(f"request {req_id}")

    # --------------------------------------------------------------- loops
    def _encode_loop(self) -> None:
        while not self._stop.is_set():
            try:
                req, sid, n, idx = self._eq.get(timeout=0.05)
            except queue.Empty:
                continue
            shard = jnp.asarray(req.mm_embeds[idx])[None]       # (1, m, d)
            tokens = np.asarray(self._encode(self.params, shard)[0])
            shards = self._shards[req.req_id]
            shards[sid] = (idx, tokens)
            if all(s is not None for s in shards):
                # ψ_EP: align + merge shard tokens (paper §3.2.2)
                M = req.mm_embeds.shape[0]
                d = tokens.shape[-1]
                merged = np.zeros((M, d), tokens.dtype)
                for s_idx, s_tok in shards:
                    merged[s_idx] = s_tok
                del self._shards[req.req_id]
                req.t_encoded = time.perf_counter()
                self._pq.put((req, merged))

    def _prefill_loop(self) -> None:
        while not self._stop.is_set():
            try:
                req, mm_tokens = self._pq.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = {"tokens": jnp.asarray(req.prompt)[None]}
            if mm_tokens is not None:
                # tokens already encoded at E; hand P the merged mm tokens
                batch["mm_embeds"] = None
            if self.cfg.family == "audio":
                batch["enc_frames"] = jnp.asarray(req.mm_embeds)[None]
            logits, cache = self._prefill_with_mm(batch, mm_tokens, req)
            tok = int(np.argmax(np.asarray(logits[0])))
            req.tokens.append(tok)
            req.t_first_token = time.perf_counter()
            # ψ_PD: cache moves to the decode stage
            self._dq.put((req, tok, cache))

    def _prefill_with_mm(self, batch, mm_tokens, req):
        S = int(batch["tokens"].shape[1])
        max_len = S + req.max_new_tokens + self.ecfg.cache_headroom
        if mm_tokens is not None:
            x_batch = dict(batch)
            x_batch.pop("mm_embeds", None)
            x_batch["mm_tokens"] = jnp.asarray(mm_tokens)[None]
            x_batch["mm_positions"] = jnp.asarray(req.mm_positions)[None]
            return _prefill_premerged(self.model, self.cfg, self.params,
                                      x_batch, max_len)
        batch = {k: v for k, v in batch.items() if v is not None}
        return self.model.prefill(self.params, batch=batch, max_len=max_len)

    def _decode_loop(self) -> None:
        # continuous batching over independent (cache, token) pairs; a TPU
        # deployment would batch these into one jitted call with paged caches
        active: list[tuple[ServeRequest, int, Any]] = []
        while not self._stop.is_set():
            while len(active) < self.ecfg.decode_batch:
                try:
                    active.append(self._dq.get_nowait())
                except queue.Empty:
                    break
            if not active:
                time.sleep(0.005)
                continue
            nxt = []
            for req, tok, cache in active:
                if len(req.tokens) >= req.max_new_tokens:
                    req.t_done = time.perf_counter()
                    with self._done_lock:
                        self._done[req.req_id] = req
                    continue
                logits, cache = self._decode(
                    self.params,
                    {"token": jnp.asarray([tok], jnp.int32), "cache": cache})
                tok = int(np.argmax(np.asarray(logits[0])))
                req.tokens.append(tok)
                nxt.append((req, tok, cache))
            active = nxt


def _prefill_premerged(model, cfg: ArchConfig, params, batch, max_len):
    """Prefill that takes ALREADY-ENCODED mm tokens (EPD path: E ran
    elsewhere). Uses the dense-stack internals with the merged embeddings."""
    from repro.models import dense
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = dense.embed_inputs(params, cfg, tokens, batch["mm_tokens"],
                           batch["mm_positions"])
    positions = jnp.arange(S)[None, :]
    h, (ks, vs), _ = dense.forward(params, cfg, x, positions, return_kv=True)
    logits = dense.lm_head(params, cfg, h[:, -1])
    if max_len > S:
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache
