"""EPD serving engine: a thin orchestrator over the typed stage graph.

Stage logic lives in ``serving.stages`` (each stage owns its jitted fns),
ψ transfer semantics in ``serving.transfer`` (ψ_EP with the
multimedia-token cache, ψ_PD block-table handoff), and request lifecycle
types in ``serving.types``. This module only wires them together:

  E workers --ψ_EP(MMTokenCache)--> P thread --ψ_PD--> D thread

``submit()`` returns a ``RequestHandle``; results arrive via blocking
``result()`` or the incremental ``stream()`` token iterator. A repeated
multimodal payload hits the ψ_EP cache at submit and skips the E stage
entirely (paper §3.2.1); preempted requests requeue through P and replay
deterministically (greedy, or seeded sampling keyed on token index).

``ServeRequest`` / ``EngineConfig`` are re-exported here as compat shims
for pre-stage-graph callers.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.serving.stages import (PAGED_FAMILIES, DenseDecodeStage,
                                  DensePrefillStage, EncodeStage,
                                  PagedDecodeStage, PagedKVState,
                                  PagedPrefillStage, ServeStats)
from repro.serving.transfer import MMTokenCache, PsiEP, PsiPD
from repro.serving.types import (EngineConfig, FinishReason, RequestHandle,
                                 RequestState, SamplingParams, ServeRequest)

__all__ = ["EPDEngine", "EngineConfig", "ServeRequest", "SamplingParams",
           "RequestState", "FinishReason", "RequestHandle", "MMTokenCache",
           "PAGED_FAMILIES"]


class EPDEngine:
    """Threaded EPD pipeline over a real model (orchestration only)."""

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.ecfg = engine
        self.paged = (engine.mode == "paged"
                      and cfg.family in PAGED_FAMILIES
                      and not cfg.sliding_window)

        self._stats = ServeStats()
        self.mm_cache = MMTokenCache(engine.mm_cache_entries)
        self.psi_ep = PsiEP(self.mm_cache)
        self.psi_pd = PsiPD()
        self.encode_stage = EncodeStage(self.model, cfg, params,
                                        engine.n_encode_workers)
        if self.paged:
            self._kv = PagedKVState(self.model, cfg, engine)
            self.kv_mgr = self._kv.mgr       # compat alias (tests, benches)
            self.prefill_stage = PagedPrefillStage(
                self.model, cfg, params, engine, self._stats, self._kv)
            self.decode_stage = PagedDecodeStage(
                self.model, cfg, params, engine, self._stats, self._kv,
                on_finish=self._finish, on_requeue=self._requeue)
        else:
            self.prefill_stage = DensePrefillStage(
                self.model, cfg, params, engine, self._stats)
            self.decode_stage = DenseDecodeStage(
                self.model, cfg, params, engine, self._stats,
                on_finish=self._finish)
        self._encode = self.encode_stage.encode_fn   # compat alias

        self._eq: queue.Queue = queue.Queue()        # encode shard jobs
        self._done: dict[int, ServeRequest] = {}
        self._done_cv = threading.Condition()
        self._handles: dict[int, RequestHandle] = {}
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    @property
    def stats(self) -> dict[str, Any]:
        return self._stats.data

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        for i in range(max(1, self.ecfg.n_encode_workers)):
            t = threading.Thread(target=self._encode_worker, daemon=True,
                                 name=f"E{i}")
            t.start()
            self._threads.append(t)
        for name, loop in (("P0", self._prefill_worker),
                           ("D0", self._decode_worker)):
            t = threading.Thread(target=loop, daemon=True, name=name)
            t.start()
            self._threads.append(t)

    def stop(self, timeout: float = 5.0) -> None:
        """Signal all stage threads and join them (deterministic shutdown)."""
        self._stop.set()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.time()))
        self._threads = [t for t in self._threads if t.is_alive()]

    # -------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> RequestHandle:
        if self.paged:
            # prefill allocates S+1 (first decode write); lifetime peak is
            # the larger of that and the full generated length
            total = max(len(req.prompt) + req.max_new_tokens,
                        len(req.prompt) + 1)
            cap = min(self.ecfg.max_seq_len,
                      self.ecfg.kv_blocks * self.ecfg.kv_block_size)
            if total > cap:
                raise ValueError(
                    f"request {req.req_id}: {total} tokens exceeds "
                    f"capacity {cap} (max_seq_len={self.ecfg.max_seq_len}, "
                    f"pool={self.ecfg.kv_blocks}x"
                    f"{self.ecfg.kv_block_size})")
        req.sampling.validate()   # seeds must fit uint32 before they jit
        req.t_submit = time.perf_counter()
        handle = RequestHandle(req=req, engine=self)
        self._handles[req.req_id] = handle
        has_mm = (req.mm_embeds is not None
                  and self.encode_stage.encode_fn is not None
                  and req.mm_embeds.shape[0] > 0)
        if not has_mm:
            req.t_encoded = time.perf_counter()
            req.advance(RequestState.PREFILLING)
            self.psi_ep.send(req, None)
            return handle
        # ψ_EP cache probe: a byte-identical modality payload skips E
        key = None
        if self.mm_cache.capacity > 0:
            key = MMTokenCache.content_key(req.mm_embeds)
            cached = self.mm_cache.get(key)
            if cached is not None:
                req.mm_cache_hit = True
                self._stats.bump("mm_cache_hits")
                req.t_encoded = time.perf_counter()
                req.advance(RequestState.PREFILLING)
                self.psi_ep.send(req, cached)
                return handle
            self._stats.bump("mm_cache_misses")
        req.advance(RequestState.ENCODING)
        shards = self.encode_stage.plan_shards(req)
        for sid, idx in enumerate(shards):
            self._eq.put((req, sid, len(shards), idx, key))
        return handle

    # ------------------------------------------------------------- results
    def result(self, req_id: int, timeout: float = 300.0) -> ServeRequest:
        deadline = time.time() + timeout
        with self._done_cv:
            while req_id not in self._done:
                remaining = deadline - time.time()
                if remaining <= 0:
                    raise TimeoutError(f"request {req_id}")
                self._done_cv.wait(remaining)
            self._handles.pop(req_id, None)    # collection point: no leak
            return self._done.pop(req_id)

    def _collect(self, req_id: int) -> None:
        """Drop a finished request from the registries (idempotent)."""
        with self._done_cv:
            self._done.pop(req_id, None)
            self._handles.pop(req_id, None)

    def stream(self, req_id: int, timeout: float = 300.0) -> Iterator[int]:
        """Incremental token iterator for an in-flight request.

        Tokens are yielded as the decode stage emits them; preemptions are
        invisible (the replay re-emits the identical prefix, the iterator
        simply pauses until generation catches back up)."""
        handle = self._handles.get(req_id)
        if handle is None:
            raise KeyError(f"unknown request {req_id}")
        return self._stream(handle.req, timeout)

    def _stream(self, req: ServeRequest, timeout: float) -> Iterator[int]:
        i = 0
        deadline = time.time() + timeout
        while True:
            with req._cv:
                while len(req.tokens) <= i and not req.finished:
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        raise TimeoutError(f"stream {req.req_id}")
                    req._cv.wait(min(remaining, 0.1))
                if len(req.tokens) > i:
                    tok = req.tokens[i]
                elif req.state is RequestState.FAILED:
                    raise RuntimeError(
                        req.error or f"request {req.req_id} failed")
                else:
                    # fully streamed: this is a collection point too, so
                    # streaming-only consumers (the README pattern) don't
                    # accumulate registry entries; handle.result() still
                    # works afterwards via the handle's own reference
                    self._collect(req.req_id)
                    return
            yield tok
            i += 1

    def _finish(self, req: ServeRequest) -> None:
        req.t_done = time.perf_counter()
        req.mark_done(FinishReason.LENGTH)
        with self._done_cv:
            self._done[req.req_id] = req
            self._done_cv.notify_all()

    def _fail(self, req: ServeRequest, error: str) -> None:
        req.t_done = time.perf_counter()
        if not req.mark_failed(error):
            return    # a concurrent failer (sibling IRP shard) beat us
        if self.paged:
            # release any pool blocks a partial prefill already allocated
            with self._kv.lock:
                self._kv.mgr.free(req.req_id)
        with self._done_cv:
            self._done[req.req_id] = req
            self._done_cv.notify_all()

    def _requeue(self, req: ServeRequest, mm_tokens) -> None:
        """Preemption: route the request back through P over ψ_EP."""
        req.advance(RequestState.PREFILLING)
        self.psi_ep.send(req, mm_tokens)

    # --------------------------------------------------------- worker loops
    def _encode_worker(self) -> None:
        while not self._stop.is_set():
            try:
                req, sid, n, idx, key = self._eq.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                tokens = self.encode_stage.encode_shard(req, idx)
                merged = self.psi_ep.add_shard(req, sid, n, idx, tokens)
                if merged is None or req.finished:
                    continue
                if key is not None:
                    self.mm_cache.put(key, merged)
                req.t_encoded = time.perf_counter()
                req.advance(RequestState.PREFILLING)
                self.psi_ep.send(req, merged)
            except Exception as e:                      # noqa: BLE001
                self._fail(req, f"encode failed: {e!r}")
                self.psi_ep.drop(req.req_id)

    def _prefill_worker(self) -> None:
        while not self._stop.is_set():
            try:
                req, mm_tokens = self.psi_ep.recv(timeout=0.05)
            except queue.Empty:
                continue
            try:
                if self.paged:
                    # head-of-line retry on a momentarily full pool:
                    # holding the request (instead of requeueing it behind
                    # later arrivals) keeps admission in FIFO order, so a
                    # long request cannot be starved by short ones
                    while not self._stop.is_set():
                        handoff = self.prefill_stage.prefill(req, mm_tokens)
                        if handoff is not None:
                            req.advance(RequestState.DECODING)
                            self.psi_pd.send(handoff)
                            break
                        time.sleep(0.01)
                else:
                    handoff = self.prefill_stage.prefill(req, mm_tokens)
                    req.advance(RequestState.DECODING)
                    self.psi_pd.send(handoff)
            except Exception as e:                      # noqa: BLE001
                self._fail(req, f"prefill failed: {e!r}")

    def _decode_worker(self) -> None:
        idle_sleep = 0.002 if self.paged else 0.005
        while not self._stop.is_set():
            try:
                worked = self.decode_stage.step(self.psi_pd)
            except Exception as e:                      # noqa: BLE001
                # e.g. a request whose appends alone exhaust the pool:
                # fail the in-flight requests instead of stranding them
                # behind a dead D thread, then keep serving new arrivals
                self.decode_stage.abort_all(
                    lambda r: self._fail(r, f"decode failed: {e!r}"))
                continue
            if not worked:
                time.sleep(idle_sleep)
