"""EPD serving engines: shared request machinery + the single-pipeline engine.

Stage logic lives in ``serving.stages`` (each stage owns its jitted fns),
ψ transfer semantics in ``serving.transfer`` (ψ_EP with the
multimedia-token cache, ψ_PD block-table handoff), the continuous-batching
loop in ``serving.scheduler``, and request lifecycle types in
``serving.types``.

``EngineBase`` is everything a serving engine needs regardless of how many
instances execute the stages: the request registry (blocking ``result()``,
incremental ``stream()``, terminal transitions under one condition
variable), admission-time validation, the ψ_EP multimedia-token cache
probe + in-flight encode dedup (anti-stampede), and the shared encode-job
body. Where requests actually GO is left to three hooks —
``_dispatch_encode`` / ``_dispatch_prefill`` / ``_release_blocks`` — so
the same machinery fronts both the single-pipeline ``EPDEngine`` below
and the multi-instance ``serving.cluster.ClusterEngine``.

``EPDEngine`` wires one pipeline:

  paged:  E workers --ψ_EP--> Scheduler thread (chunked P + batched D)
  dense:  E workers --ψ_EP--> P thread --ψ_PD--> D thread  (baseline)

``submit()`` returns a ``RequestHandle``; results arrive via blocking
``result()`` or the incremental ``stream()`` token iterator. A repeated
multimodal payload hits the ψ_EP cache at submit and skips the E stage
entirely (paper §3.2.1) — and a byte-identical payload already being
encoded is joined in-flight, so concurrent duplicates never stampede the
encoder. Preempted requests requeue through P and replay
deterministically (greedy, or seeded sampling keyed on token index).
``stop()`` drains every channel and fails resident requests, so a
concurrent ``result()``/``stream()`` returns promptly instead of timing
out.

``ServeRequest`` / ``EngineConfig`` are re-exported here as compat shims
for pre-stage-graph callers.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Iterator, Optional

from repro.configs.base import ArchConfig
from repro.kernels.registry import resolve_backend
from repro.models import build_model
from repro.serving.runner import ModelRunner
from repro.serving.scheduler import Scheduler
from repro.serving.stages import (PAGED_FAMILIES, DenseDecodeStage,
                                  DensePrefillStage, EncodeStage,
                                  PagedDecodeStage, PagedJitKit,
                                  PagedKVState, PagedPrefillStage,
                                  ServeStats, cache_nbytes)
from repro.serving.transfer import (MMTokenCache, PsiEP, PsiPD,
                                    drain_queue)
from repro.serving.types import (EngineConfig, FinishReason, RequestHandle,
                                 RequestState, RequestTimeout, SamplingParams,
                                 ServeRequest)

__all__ = ["EngineBase", "EPDEngine", "EngineConfig", "ServeRequest",
           "SamplingParams", "RequestState", "FinishReason", "RequestHandle",
           "RequestTimeout", "MMTokenCache", "PAGED_FAMILIES"]


class EngineBase:
    """Request registry + submit-side machinery shared by every engine.

    Subclasses implement the routing hooks:
      * ``_dispatch_encode(req, key)``  — queue the planned IRP shards,
      * ``_dispatch_prefill(req, mm_tokens)`` — hand a prefill-ready
        request to a P stage (possibly choosing an instance),
      * ``_release_blocks(req)`` — free any pool blocks a failed request
        still holds,
    and may override ``_on_submit`` (workload observation) and
    ``_check_mm`` (reject modality payloads the topology cannot encode).
    """

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.ecfg = engine
        if engine.runner not in ("packed", "two_program"):
            raise ValueError(
                f"unknown runner {engine.runner!r}; "
                f"expected 'packed' or 'two_program'")
        # resolves EngineConfig.attn_backend / $REPRO_ATTN_BACKEND and
        # fails fast on unknown names (env typos cannot silently fall
        # back to the default backend)
        self.backend = resolve_backend(engine.attn_backend)
        self.paged = (engine.mode == "paged"
                      and cfg.family in PAGED_FAMILIES
                      and not cfg.sliding_window)
        self._stats = ServeStats()
        self.mm_cache = MMTokenCache(engine.mm_cache_entries)
        self.psi_ep = PsiEP(self.mm_cache)
        self._stop = threading.Event()
        # in-flight encode dedup: content key -> requests waiting for the
        # first submitter's merged tokens (anti-stampede)
        self._mm_inflight: dict[str, list[ServeRequest]] = {}
        # req_id -> content key for requests currently LEADING an
        # in-flight encode; an aborted leader must promote a waiter (see
        # ``abort``) or its waiters would strand forever
        self._mm_leading: dict[int, str] = {}
        self._mm_lock = threading.Lock()
        self._done: dict[int, ServeRequest] = {}
        self._done_cv = threading.Condition()
        self._handles: dict[int, RequestHandle] = {}
        self._threads: list[threading.Thread] = []

    @property
    def stats(self) -> dict[str, Any]:
        return self._stats.data

    # -------------------------------------------------------------- hooks
    def _dispatch_encode(self, req: ServeRequest,
                         key: Optional[str]) -> None:
        raise NotImplementedError

    def _dispatch_prefill(self, req: ServeRequest, mm_tokens) -> None:
        raise NotImplementedError

    def _release_blocks(self, req: ServeRequest) -> None:
        """Free pool blocks a failed request may still hold (paged)."""

    def _on_submit(self, req: ServeRequest) -> None:
        """Called once per admitted request (workload observation)."""

    def _check_mm(self, req: ServeRequest) -> None:
        """Reject modality payloads the topology cannot encode."""

    def _has_encoder(self) -> bool:
        raise NotImplementedError

    # -------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> RequestHandle:
        # admission-time length validation in BOTH modes: the lifetime
        # peak is prompt + generated tokens. max_new >= 1 is required —
        # it covers prefill's S+1 first-decode-write headroom, so a
        # zero-generation request can't pass validation yet be
        # unadmittable forever (wedging the FIFO head)
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.req_id}: max_new_tokens must be >= 1")
        if len(req.prompt) < 1:
            # both execution paths assume at least one prompt token (a
            # zero-length prefill has no last-token row to sample from)
            raise ValueError(f"request {req.req_id}: empty prompt")
        total = len(req.prompt) + req.max_new_tokens
        cap = self.ecfg.max_seq_len
        if self.paged:
            cap = min(cap, self.ecfg.kv_blocks * self.ecfg.kv_block_size)
        if total > cap:
            raise ValueError(
                f"request {req.req_id}: {total} tokens exceeds capacity "
                f"{cap} (max_seq_len={self.ecfg.max_seq_len}"
                + (f", pool={self.ecfg.kv_blocks}x"
                   f"{self.ecfg.kv_block_size})" if self.paged else ")"))
        req.sampling.validate()   # seeds must fit uint32 before they jit
        if req.mm_embeds is not None and req.mm_embeds.shape[0] > 0:
            self._check_mm(req)
        req.t_submit = time.perf_counter()
        self._on_submit(req)
        handle = RequestHandle(req=req, engine=self)
        self._handles[req.req_id] = handle
        has_mm = (req.mm_embeds is not None
                  and self._has_encoder()
                  and req.mm_embeds.shape[0] > 0)
        if not has_mm:
            req.t_encoded = time.perf_counter()
            req.advance(RequestState.PREFILLING)
            self._dispatch_prefill(req, None)
            return handle
        # ψ_EP cache probe: a byte-identical modality payload skips E
        key = None
        if self.mm_cache.capacity > 0:
            key = MMTokenCache.content_key(req.mm_embeds)
            cached = self.mm_cache.get(key)
            if cached is not None:
                req.mm_cache_hit = True
                self._stats.bump("mm_cache_hits")
                req.t_encoded = time.perf_counter()
                req.advance(RequestState.PREFILLING)
                self._dispatch_prefill(req, cached)
                return handle
            self._stats.bump("mm_cache_misses")
            # anti-stampede: if a byte-identical payload is ALREADY being
            # encoded, wait for its merged tokens instead of running the
            # IRP shards a second time
            with self._mm_lock:
                waiters = self._mm_inflight.get(key)
                if waiters is not None:
                    req.advance(RequestState.ENCODING)
                    waiters.append(req)
                    self._stats.bump("mm_inflight_hits")
                    return handle
                self._mm_inflight[key] = []
                self._mm_leading[req.req_id] = key
        req.advance(RequestState.ENCODING)
        self._dispatch_encode(req, key)
        return handle

    # ------------------------------------------------------------- results
    def result(self, req_id: int, timeout: float = 300.0) -> ServeRequest:
        handle = self._handles.get(req_id)
        if handle is not None:
            return self._result_of(handle.req, timeout)
        with self._done_cv:                    # already collected elsewhere?
            if req_id in self._done:
                self._handles.pop(req_id, None)
                return self._done.pop(req_id)
        raise KeyError(f"unknown request {req_id}")

    def _result_of(self, req: ServeRequest, timeout: float) -> ServeRequest:
        """Block until ``req`` reaches a terminal state, then collect it.

        Waits on the request's terminal state rather than the ``_done``
        registry, so a concurrent stream consumer collecting the same
        request cannot strand this waiter (the registry pop is idempotent
        and happens strictly after the terminal transition — both are
        made under ``_done_cv``)."""
        deadline = time.time() + timeout
        with self._done_cv:
            while not req.finished:
                remaining = deadline - time.time()
                if remaining <= 0:
                    raise RequestTimeout(req.req_id, timeout)
                self._done_cv.wait(remaining)
            self._done.pop(req.req_id, None)   # collection point: no leak
            self._handles.pop(req.req_id, None)
        return req

    def _collect(self, req_id: int) -> None:
        """Drop a finished request from the registries (idempotent)."""
        with self._done_cv:
            self._done.pop(req_id, None)
            self._handles.pop(req_id, None)

    def collect(self, req_id: int) -> None:
        """Public collection point for callers that consumed a request
        through side channels (the gateway after an abort, the LB after
        a response is written) — ``result()`` collects implicitly, this
        covers the paths that never call it."""
        self._collect(req_id)

    def stream(self, req_id: int, timeout: float = 300.0) -> Iterator[int]:
        """Incremental token iterator for an in-flight request.

        Tokens are yielded as the decode stage emits them; preemptions are
        invisible (the replay re-emits the identical prefix, the iterator
        simply pauses until generation catches back up)."""
        handle = self._handles.get(req_id)
        if handle is None:
            raise KeyError(f"unknown request {req_id}")
        return self._stream(handle.req, timeout)

    def _stream(self, req: ServeRequest, timeout: float) -> Iterator[int]:
        i = 0
        deadline = time.time() + timeout
        while True:
            done = False
            with req._cv:
                while len(req.tokens) <= i and not req.finished:
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        raise RequestTimeout(req.req_id, timeout)
                    req._cv.wait(min(remaining, 0.1))
                if len(req.tokens) > i:
                    tok = req.tokens[i]
                elif req.state is RequestState.FAILED:
                    raise RuntimeError(
                        req.error or f"request {req.req_id} failed")
                else:
                    done = True
            if done:
                # fully streamed: this is a collection point too, so
                # streaming-only consumers (the README pattern) don't
                # accumulate registry entries; handle.result() still works
                # afterwards via the handle's own reference. Collected
                # OUTSIDE req._cv — _collect takes _done_cv, and the lock
                # order is _done_cv -> req._cv everywhere else.
                self._collect(req.req_id)
                return
            yield tok
            i += 1

    def _finish(self, req: ServeRequest) -> None:
        req.t_done = time.perf_counter()
        # terminal transition + registry insert are one atomic unit under
        # _done_cv (lock order: _done_cv -> req._cv), so _result_of can
        # never observe `finished` without the _done entry in place
        with self._done_cv:
            req.mark_done(FinishReason.STOP if req.stop_hit
                          else FinishReason.LENGTH)
            self._done[req.req_id] = req
            self._done_cv.notify_all()

    def _fail(self, req: ServeRequest, error: str, *,
              release: bool = True) -> None:
        req.t_done = time.perf_counter()
        with self._done_cv:
            claimed = req.mark_failed(error)
            if claimed:
                self._done[req.req_id] = req
                self._done_cv.notify_all()
        if not claimed:
            return    # a concurrent failer (sibling IRP shard) beat us
        if release:
            self._release_blocks(req)

    # --------------------------------------------------------------- abort
    def abort(self, req_id: int,
              reason: str = "aborted by client") -> bool:
        """Cancel a non-terminal request (client disconnect / explicit
        cancel). Transitions it to FAILED(``reason``), wakes concurrent
        ``result()``/``stream()`` waiters, drops its ψ_EP shard assembly,
        and releases its KV blocks. Returns True if this call claimed the
        cancellation, False if the request was unknown or already
        terminal.

        Block release is DEFERRED to the stage sweeps while the engine is
        running — the scheduler/runner may hold the request's block table
        inside an in-flight iteration, so freeing from this (external)
        thread could reallocate blocks under a live forward. Every stage
        already sweeps FAILED requests on its own thread: the admission
        queue skips them, the scheduler abandons an in-flight prefill
        task, and the decode stage retires finished slots — each sweep
        frees the blocks. Only when no worker threads are alive is the
        free performed directly here."""
        handle = self._handles.get(req_id)
        if handle is None:
            return False
        req = handle.req
        with self._done_cv:
            if req.finished:
                return False
        self._fail(req, reason, release=not self._running())
        self.psi_ep.drop(req_id)
        self._promote_mm_leader(req)
        self._stats.bump("aborts")
        return True

    def _promote_mm_leader(self, req: ServeRequest) -> None:
        """If ``req`` was leading an in-flight encode with waiters parked
        behind it, hand leadership to the first live waiter and re-run
        its encode — the aborted leader's remaining shards tombstone in
        ψ_EP (``add_shard`` sees the FAILED state), so without promotion
        the waiters would never receive merged tokens. Aborted waiters
        are simply removed from whatever list they sit in."""
        with self._mm_lock:
            for ws in self._mm_inflight.values():
                if req in ws:
                    ws.remove(req)
            key = self._mm_leading.pop(req.req_id, None)
            new_leader = None
            if key is not None and key in self._mm_inflight:
                waiters = self._mm_inflight.pop(key)
                while waiters and waiters[0].finished:
                    waiters.pop(0)
                if waiters:
                    new_leader = waiters.pop(0)
                    self._mm_inflight[key] = waiters
                    self._mm_leading[new_leader.req_id] = key
        if new_leader is not None:
            self._dispatch_encode(new_leader, key)

    # ------------------------------------------------------------- health
    def _running(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    def queue_depth(self) -> int:
        """Queued + resident work items (load-balancer pressure signal)."""
        return 0

    def kv_block_counts(self) -> tuple[int, int]:
        """(free, total) KV pool blocks across the engine; (0, 0) when
        the engine has no paged pool (dense baseline)."""
        return (0, 0)

    def current_roles(self) -> list[str]:
        """Stage letters served, one entry per instance."""
        return ["EPD"]

    def instance_states(self) -> dict[str, int]:
        """Fleet liveness counts (the ClusterEngine reports per-instance
        deaths and elastic retirements; single-pipeline engines are one
        implicit instance)."""
        return {"alive": 1 if self._running() else 0, "dead": 0,
                "retiring": 0}

    def health(self) -> dict[str, Any]:
        """Liveness + pressure snapshot (gateway /health, LB probes)."""
        free, total = self.kv_block_counts()
        return {"ok": self._running(), "roles": self.current_roles(),
                "queue_depth": self.queue_depth(),
                "kv_free_blocks": free, "kv_total_blocks": total,
                "instances": self.instance_states()}

    # --------------------------------------------- encode–prefill overlap
    def _overlap_capable(self) -> bool:
        """Whether this engine's P path can consume a live ShardStream
        (the paged scheduler loops gate chunks at the encoded watermark;
        the dense baseline prefills whole prompts only)."""
        return False

    def _open_overlap_stream(self, req: ServeRequest, n_shards: int):
        """Encode–prefill overlap: for a multi-shard request on a
        stream-capable P path, switch ψ_EP to streaming publication and
        return the stream. None keeps the buffered full-merge path —
        overlap off, or the documented no-op cases (text-only requests
        never reach here; single-shard requests have no tail to hide)."""
        if (not self.ecfg.encode_overlap or n_shards < 2
                or not self._overlap_capable()):
            return None
        return self.psi_ep.open_stream(req)

    def _start_streaming_prefill(self, req: ServeRequest, stream) -> None:
        """Admit a still-encoding request to P NOW: the scheduler's
        chunk frontier trails the stream's encoded watermark while the
        remaining shards encode."""
        try:
            req.advance(RequestState.PREFILLING)
        except ValueError:
            if req.finished:      # aborted between dispatch and here
                return
            raise
        self._dispatch_prefill(req, stream)

    # --------------------------------------------------- encode-side shared
    def _run_encode_shard(self, stage: EncodeStage, req: ServeRequest,
                          sid: int, n: int, idx, key: Optional[str]) -> None:
        """One IRP shard job: encode, assemble, and on the final shard
        cache + dispatch the merged tokens (identical on every engine).

        A finished (aborted) leader's shards skip the encoder — ψ_EP
        tombstones its assembly anyway, and ``abort`` has already
        promoted a waiter to re-lead the key."""
        if req.finished:
            return
        try:
            tokens = stage.encode_shard(req, idx)
            self._finish_encode_shard(req, sid, n, idx, key, tokens)
        except Exception as e:                      # noqa: BLE001
            self._encode_job_failed(req, key, f"encode failed: {e!r}")

    def _finish_encode_shard(self, req: ServeRequest, sid: int, n: int,
                             idx, key: Optional[str], tokens) -> None:
        """Post-encode half of a shard job: assemble over ψ_EP and, on
        the full merge, cache + deliver waiters + dispatch.

        Waiters are delivered BEFORE the leader advances, so a leader
        aborted between the merge and its own dispatch can never drag
        its waiters down with it. A streaming (overlap) request is
        already PREFILLING against the live stream, so the merge only
        commits the cache and delivers waiters — never re-dispatches."""
        streaming = self.psi_ep.has_stream(req.req_id)
        merged = self.psi_ep.add_shard(req, sid, n, idx, tokens)
        if merged is None:
            return
        if key is not None:
            # full-merge guard: a partial/streaming shard set must never
            # commit a truncated entry for dedup followers
            self.mm_cache.put(key, merged,
                              n_expected=req.mm_embeds.shape[0])
        self._deliver_inflight(req, key, merged)
        if req.finished:
            return
        req.t_encoded = time.perf_counter()
        if streaming:
            return
        req.advance(RequestState.PREFILLING)
        self._dispatch_prefill(req, merged)

    def _encode_job_failed(self, req: ServeRequest, key: Optional[str],
                           error: str) -> None:
        """Shared failure tail for a shard job (threaded or lane): fail
        the leader, drop its ψ_EP assembly/stream, and fail the
        byte-identical waiters (they would fail identically)."""
        self._fail(req, error)
        self.psi_ep.drop(req.req_id)
        self._fail_inflight(req, key, error)

    def _lane_shard_done(self, stage: EncodeStage, work, tokens) -> None:
        """Completion hook for a lane-executed shard (scheduler thread,
        from inside ``ModelRunner.execute``): identical post-half to a
        threaded E worker, including shard accounting and failure
        routing."""
        stage.note_shards()
        try:
            self._finish_encode_shard(work.req, work.sid, work.n_shards,
                                      work.idx, work.key, tokens)
        except Exception as e:                      # noqa: BLE001
            self._encode_job_failed(work.req, work.key,
                                    f"encode failed: {e!r}")

    def _deliver_inflight(self, leader: Optional[ServeRequest],
                          key: Optional[str], merged) -> None:
        """Hand the leader's merged mm tokens to every waiter that joined
        the in-flight encode of the same content key."""
        if key is None:
            return
        with self._mm_lock:
            waiters = self._mm_inflight.pop(key, [])
            if leader is not None:
                self._mm_leading.pop(leader.req_id, None)
        for w in waiters:
            if w.finished:
                continue
            w.mm_cache_hit = True
            w.t_encoded = time.perf_counter()
            w.advance(RequestState.PREFILLING)
            self._dispatch_prefill(w, merged)

    def _fail_inflight(self, leader: Optional[ServeRequest],
                       key: Optional[str], error: str) -> None:
        if key is None:
            return
        with self._mm_lock:
            waiters = self._mm_inflight.pop(key, [])
            if leader is not None:
                self._mm_leading.pop(leader.req_id, None)
        for w in waiters:
            self._fail(w, error)

    def _fail_residents(self, error: str) -> None:
        """Fail every registered-but-unfinished request (shutdown sweep)."""
        with self._mm_lock:
            self._mm_inflight.clear()
            self._mm_leading.clear()
        for handle in list(self._handles.values()):
            if not handle.req.finished:
                self._fail(handle.req, error)

    def _join_threads(self, timeout: float) -> None:
        """Shutdown step 1, shared by every engine: signal the stop flag
        and join all worker threads.

        ``timeout`` is the expected join horizon, not a hard cap: a
        worker stuck past it (e.g. a long XLA compile) is joined to
        completion anyway — every loop re-checks the stop flag after its
        current bounded step, and draining while a worker lives would
        free blocks under its feet."""
        self._stop.set()
        deadline = time.time() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.time()))
        for t in self._threads:
            if t.is_alive():
                t.join()
        self._threads = []


class EPDEngine(EngineBase):
    """Threaded single-pipeline EPD engine over a real model."""

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig):
        super().__init__(cfg, params, engine)
        self.encode_stage = EncodeStage(self.model, cfg, params,
                                        engine.n_encode_workers,
                                        stats=self._stats)
        self.psi_pd = PsiPD()
        self.scheduler: Scheduler | None = None
        if self.paged:
            kit = PagedJitKit(self.model, cfg, backend=self.backend)
            self.kit = kit
            self._kv = PagedKVState(self.model, cfg, engine, kit=kit,
                                    stats=self._stats)
            self.kv_mgr = self._kv.mgr       # compat alias (tests, benches)
            self.prefill_stage = PagedPrefillStage(
                self.model, cfg, params, engine, self._stats, self._kv,
                kit=kit)
            if engine.runner == "packed":
                # the token-packed ModelRunner IS the decode stage, plus
                # the chunk-execution half of the scheduler iteration
                self.decode_stage = ModelRunner(
                    self.model, cfg, params, engine, self._stats, self._kv,
                    on_finish=self._finish, on_requeue=self._requeue,
                    kit=kit)
                runner = self.decode_stage
            else:
                self.decode_stage = PagedDecodeStage(
                    self.model, cfg, params, engine, self._stats, self._kv,
                    on_finish=self._finish, on_requeue=self._requeue,
                    kit=kit)
                runner = None
            self.scheduler = Scheduler(
                engine, self.prefill_stage, self.decode_stage,
                self.psi_ep, self.psi_pd, self._stats, self._stop,
                on_fail=self._fail, runner=runner)
            # packed encode lanes: shard jobs go to the scheduler's
            # iteration plan instead of the E worker threads
            self._lanes = (engine.encode_lanes and runner is not None
                           and runner.max_encode_groups > 0)
            if self._lanes:
                runner.on_encoded = (
                    lambda w, t: self._lane_shard_done(self.encode_stage,
                                                       w, t))
                self.scheduler.on_encode_fail = self._encode_job_failed
        else:
            self.prefill_stage = DensePrefillStage(
                self.model, cfg, params, engine, self._stats,
                backend=self.backend)
            self.decode_stage = DenseDecodeStage(
                self.model, cfg, params, engine, self._stats,
                on_finish=self._finish, backend=self.backend)
        if self.scheduler is None:
            self._lanes = False               # dense baseline: E threads
        self._encode = self.encode_stage.encode_fn   # compat alias
        self._eq: queue.Queue = queue.Queue()        # encode shard jobs

    # ------------------------------------------------------- routing hooks
    def _has_encoder(self) -> bool:
        return self.encode_stage.encode_fn is not None

    def _overlap_capable(self) -> bool:
        return self.scheduler is not None

    def _dispatch_prefill(self, req: ServeRequest, mm_tokens) -> None:
        self.psi_ep.send(req, mm_tokens)

    def _dispatch_encode(self, req: ServeRequest,
                         key: Optional[str]) -> None:
        shards = self.encode_stage.plan_shards(req)
        stream = self._open_overlap_stream(req, len(shards))
        for sid, idx in enumerate(shards):
            job = (req, sid, len(shards), idx, key)
            if self._lanes:
                self.scheduler.submit_encode_job(job)
            else:
                self._eq.put(job)
        if stream is not None:
            # overlap: admit to P immediately; the chunk frontier trails
            # the stream's encoded watermark
            self._start_streaming_prefill(req, stream)

    def _release_blocks(self, req: ServeRequest) -> None:
        if self.paged:
            # release any pool blocks a partial prefill already allocated
            with self._kv.lock:
                self._kv.mgr.free(req.req_id)

    # ------------------------------------------------------------- health
    def queue_depth(self) -> int:
        n = self._eq.qsize() + self.psi_ep.qsize()
        if self.scheduler is not None:
            n += (len(self.scheduler.queue)
                  + len(self.scheduler.encode_q)
                  + int(self.scheduler.task is not None)
                  + self.psi_pd.qsize()
                  + self.decode_stage.active_count)
        return n

    def kv_block_counts(self) -> tuple[int, int]:
        if not self.paged:
            return (0, 0)
        with self._kv.lock:
            return (self._kv.mgr.free_blocks, self.ecfg.kv_blocks)

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        for i in range(max(1, self.ecfg.n_encode_workers)):
            t = threading.Thread(target=self._encode_worker, daemon=True,
                                 name=f"E{i}")
            t.start()
            self._threads.append(t)
        if self.scheduler is not None:
            # paged: ONE worker drives the continuous-batching scheduler
            # (chunked prefill + batched decode co-scheduled per iteration)
            loops = (("S0", self._sched_worker),)
        else:
            loops = (("P0", self._prefill_worker),
                     ("D0", self._decode_worker))
        for name, loop in loops:
            t = threading.Thread(target=loop, daemon=True, name=name)
            t.start()
            self._threads.append(t)

    def stop(self, timeout: float = 5.0) -> None:
        """Signal all stage threads, join them (see ``_join_threads``),
        then fail every resident (unfinished) request so concurrent
        ``result()``/``stream()`` callers return promptly instead of
        hitting their timeouts."""
        self._join_threads(timeout)
        self._drain_on_stop()

    def _drain_on_stop(self) -> None:
        """Empty every channel and fail stranded requests (clean shutdown).

        Residents can be parked in the encode shard queue, the ψ_EP/ψ_PD
        channels, the scheduler's admission queue or in-flight chunked
        prefill, a decode slot, or waiting on an in-flight encode key —
        all of them are registered in ``_handles`` until collected, so one
        sweep fails them all; channel drains release the block/cache
        resources the handoffs still reference."""
        error = "engine stopped before the request completed"
        drain_queue(self._eq)                         # encode shard jobs
        self.psi_ep.drain()
        for handoff in self.psi_pd.drain():
            if not self.paged:                        # materialized cache
                self._stats.sub_live(cache_nbytes(handoff[2]))
        if self.scheduler is not None:
            for req in self.scheduler.drain():        # frees task blocks
                self._fail(req, error)
        self._fail_residents(error)

    def _requeue(self, req: ServeRequest, mm_tokens) -> None:
        """Preemption: re-admit through P — at the FRONT of the
        scheduler's queue (paged), or over ψ_EP (dense baseline)."""
        req.advance(RequestState.PREFILLING)
        if self.scheduler is not None:
            self.scheduler.requeue(req, mm_tokens)
        else:
            self.psi_ep.send(req, mm_tokens)

    # --------------------------------------------------------- worker loops
    def _encode_worker(self) -> None:
        while not self._stop.is_set():
            try:
                req, sid, n, idx, key = self._eq.get(timeout=0.05)
            except queue.Empty:
                continue
            self._run_encode_shard(self.encode_stage, req, sid, n, idx, key)

    def _sched_worker(self) -> None:
        """Paged mode: ONE loop drives the continuous-batching scheduler
        (chunked prefill co-scheduled with the batched decode step)."""
        while not self._stop.is_set():
            try:
                worked = self.scheduler.step()
            except Exception as e:                      # noqa: BLE001
                # per-request failures are handled inside step(); this
                # catches scheduler bugs so the loop never dies silently
                self.decode_stage.abort_all(
                    lambda r: self._fail(r, f"scheduler failed: {e!r}"))
                continue
            if not worked:
                time.sleep(0.002)

    def _prefill_worker(self) -> None:
        """Dense baseline: free-running P thread (unchunked prefill)."""
        while not self._stop.is_set():
            try:
                req, mm_tokens = self.psi_ep.recv(timeout=0.05)
            except queue.Empty:
                continue
            try:
                handoff = self.prefill_stage.prefill(req, mm_tokens)
                if req.finished:      # aborted mid-prefill: drop the cache
                    self._stats.sub_live(cache_nbytes(handoff[2]))
                    continue
                req.advance(RequestState.DECODING)
                self.psi_pd.send(handoff)
            except Exception as e:                      # noqa: BLE001
                self._fail(req, f"prefill failed: {e!r}")

    def _decode_worker(self) -> None:
        """Dense baseline: free-running D thread."""
        while not self._stop.is_set():
            try:
                worked = self.decode_stage.step(self.psi_pd)
            except Exception as e:                      # noqa: BLE001
                # e.g. a request whose appends alone exhaust the pool:
                # fail the in-flight requests instead of stranding them
                # behind a dead D thread, then keep serving new arrivals
                self.decode_stage.abort_all(
                    lambda r: self._fail(r, f"decode failed: {e!r}"))
                continue
            if not worked:
                time.sleep(0.005)
