"""Multi-instance cluster engine with dynamic role switching (§3.2.4).

The paper's headline mechanism — dedicating separate instances to E, P
and D and re-roling them as the workload shifts — previously existed only
in the discrete-event simulator (``core.simulator``). ``ClusterEngine``
is the real-execution counterpart: N instances, each an
``InstanceWorker`` owning its OWN stage objects, KV/MM pools and ONE
serialized executor thread (exactly the structure of
``core.instance.Instance``), wired by the ψ channels of
``serving.transfer`` and fronted by a router that reuses the
``core.scheduler`` assignment policies:

  ``"2E1P1D"``  true EPD disaggregation (ours)
  ``"4EPD"``    every instance aggregated — the vLLM baseline
  ``"3EP1D"``   prefill/decode disaggregation only — DistServe

all through the one ``submit()/result()/stream()`` API of
``EngineBase``. Within one process "an instance" is a worker thread with
private pools; on real hardware it would be a submesh — the queueing
structure, block-manager gating, and migration logic are identical,
which is what the sim-vs-real cross-validation tests rely on.

Transfers: ψ_EP moves merged multimodal tokens (IRP shards may encode on
DIFFERENT E instances; the shared assembler in ``EngineBase`` merges
them). ψ_PD between co-located P and D stages stays a block-table
reference; between instances it becomes a real cache migration — the
prompt KV is copied out of the prefill worker's pool
(``PagedKVState.extract``) and injected into the decode worker's pool
(``inject``), byte-exact, so migrated decode is bit-identical to local
decode. A ``"1EPD"`` cluster therefore emits the same greedy token
streams as the single-pipeline ``EPDEngine``.

Dynamic role switching (paper §3.2.4: offload -> migrate -> onload,
switch < 0.7 s): a monitor thread reads per-stage demand from
``core.load_estimator.LoadEstimator`` (fed by ``submit()``), and when
the suggested allocation disagrees with the current one, re-roles an
idle single-letter instance: stop accepting, offload queued work to
siblings, wait for in-flight work to drain, swap stage set + pools
(compiled programs live in the shared ``PagedJitKit`` — no recompile),
then sit out a cooldown (anti-thrash). A stage never drops to zero
instances: donors must have >= 2 instances serving their letter.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Optional, Union

from repro.configs.base import ArchConfig
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import A100_80G, HardwareProfile
from repro.core.faults import FaultPlan
from repro.core.instance import D_ROLES, E_ROLES, P_ROLES
from repro.core.load_estimator import LoadEstimator
from repro.core.scheduler import (LATENCY_AWARE, LEAST_LOADED, ROUND_ROBIN,
                                  Assigner)
from repro.serving.engine import EngineBase
from repro.serving.runner import ModelRunner
from repro.serving.scheduler import Scheduler
from repro.serving.stages import (EncodeStage, PagedDecodeStage, PagedJitKit,
                                  PagedKVState, PagedPrefillStage)
from repro.serving.transfer import MigratedPrefill, PsiEP, PsiPD
from repro.serving.types import (ClusterConfig, EngineConfig, RequestState,
                                 ServeRequest)

__all__ = ["ClusterEngine", "ClusterConfig", "InstanceWorker"]

_POLICIES = {"least_loaded": LEAST_LOADED, "round_robin": ROUND_ROBIN,
             "latency_aware": LATENCY_AWARE}


def _task_payload(task):
    """Re-admission payload for an abandoned prefill task. A task whose
    streamed ψ_EP shards have not all landed re-admits with its LIVE
    :class:`ShardStream` (the replay gates on the same watermark and the
    stream keeps filling wherever the surviving shards encode); a fully
    merged task re-admits with its token set as before."""
    st = getattr(task, "stream", None)
    if st is not None and task.mm_tokens is None:
        return st.merged if st.merged is not None else st
    return task.mm_tokens


class _NullDecode:
    """Decode stand-in for P-only instances: the shared ``Scheduler``
    co-schedules decode and prefill; with no D stage on the instance the
    whole token budget goes to prefill chunks."""

    active_count = 0

    def step(self, psi_pd) -> int:
        return 0

    def abort_all(self, on_fail) -> None:
        pass


class _MigratingPsiPD:
    """ψ_PD for a P-instance with no local D stage: ``send`` performs the
    PD cache migration — copy the prompt KV out of the source pool, free
    it there, and route the payload to a decode instance (the paper's
    'KV cache migrates'). Runs on the P instance's executor thread."""

    def __init__(self, cluster: "ClusterEngine", src: "InstanceWorker"):
        self.cluster = cluster
        self.src = src
        self.transfers = 0

    def send(self, task) -> None:
        req = task.req
        k, v = self.src.kv.extract(req.req_id)
        self.transfers += 1
        self.cluster._stats.bump("pd_migrations")
        payload = MigratedPrefill(req=req, first_tok=task.first_tok,
                                  total=task.total, mm_tokens=task.mm_tokens,
                                  k_blocks=k, v_blocks=v, keys=task.keys,
                                  x_last=(task.x_last
                                          if task.first_tok is None
                                          else None))
        try:
            self.cluster._route_migration(payload)
        except RuntimeError as e:
            self.cluster._fail(req, f"pd migration failed: {e!r}")

    def qsize(self) -> int:
        return 0

    def drain(self) -> list:
        return []


class InstanceWorker:
    """One engine instance: a (switchable) role, its own stages + pools,
    and one serialized executor thread driving every stage it serves."""

    def __init__(self, iid: int, role: str, cluster: "ClusterEngine"):
        self.iid = iid
        self.cluster = cluster
        self.accepting = True
        self.alive = True             # cleared by the fault shim on death
        self.failed_over = False      # supervisor re-homed the residents
        self.retired = False          # elastic scale-down drain completed
        self._retiring = False        # executor-side retirement in progress
        self._lat_ewma: Optional[float] = None
        self.cooldown_until = 0.0
        self.role_since = time.perf_counter()
        self._pending_role: Optional[str] = None
        # cluster-facing channels — created ONCE and kept across role
        # switches so router threads never hold a stale reference
        self.enc_q: queue.Queue = queue.Queue()       # (req, sid, n, idx, key)
        self.psi_in = PsiEP(cluster.mm_cache)         # admissions (req, mm)
        self.requeue_q: queue.Queue = queue.Queue()   # preemption re-admits
        self.mig_q: deque = deque()                   # inbound MigratedPrefill
        self._mig_lock = threading.Lock()
        self.thread: Optional[threading.Thread] = None
        self.role = role
        self._build_role(role)

    # -------------------------------------------------------------- roles
    def serves(self, letter: str) -> bool:
        roles = {"E": E_ROLES, "P": P_ROLES, "D": D_ROLES}[letter]
        return self.role in roles

    def _build_role(self, role: str) -> None:
        """Instantiate the stage set + pools for ``role``. The jitted
        programs come from the cluster's shared ``PagedJitKit``, so this
        is cheap — a role switch never recompiles."""
        c = self.cluster
        self.role = role
        e = role in E_ROLES
        p = role in P_ROLES
        d = role in D_ROLES
        packed = c.ecfg.runner == "packed"
        self.encode_stage = (
            EncodeStage(c.model, c.cfg, c.params, c.ecfg.n_encode_workers,
                        kit=c.kit, stats=c._stats) if e else None)
        self.kv = (PagedKVState(c.model, c.cfg, c.ecfg, kit=c.kit,
                                stats=c._stats)
                   if (p or d) else None)
        self.prefill_stage = (
            PagedPrefillStage(c.model, c.cfg, c.params, c.ecfg, c._stats,
                              self.kv, kit=c.kit) if p else None)
        if d:
            stage_cls = ModelRunner if packed else PagedDecodeStage
            self.decode_stage = stage_cls(
                c.model, c.cfg, c.params, c.ecfg, c._stats, self.kv,
                on_finish=c._finish, on_requeue=c._requeue, kit=c.kit)
        else:
            self.decode_stage = None
        self.psi_pd = PsiPD() if d else None
        self.scheduler: Optional[Scheduler] = None
        if p:
            psi_pd_out = (self.psi_pd if d
                          else _MigratingPsiPD(c, self))
            if packed:
                # the runner executes this instance's packed iterations;
                # a P-only instance gets a ZERO-slot runner (all budget
                # goes to prefill chunks; ψ_PD is never polled)
                runner = (self.decode_stage if d else ModelRunner(
                    c.model, c.cfg, c.params, c.ecfg, c._stats, self.kv,
                    on_finish=c._finish, on_requeue=c._requeue, kit=c.kit,
                    n_slots=0))
            else:
                runner = None
            self.scheduler = Scheduler(
                c.ecfg, self.prefill_stage,
                self.decode_stage if d else _NullDecode(),
                self.psi_in, psi_pd_out, c._stats, c._stop,
                on_fail=c._fail, runner=runner)
        # encode lanes: on an instance serving BOTH E and a packed
        # prefill/decode scheduler, shard jobs fold into the runner's
        # per-iteration packed plan instead of the threaded encode pool
        self._lanes = (c.ecfg.encode_lanes and e
                       and self.scheduler is not None
                       and self.scheduler.runner is not None
                       and self.scheduler.runner.max_encode_groups > 0)
        if self._lanes:
            self.scheduler.runner.on_encoded = (
                lambda w, t, _s=self.encode_stage:
                c._lane_shard_done(_s, w, t))
            self.scheduler.on_encode_fail = c._encode_job_failed

    # --------------------------------------------------------------- load
    def load(self) -> float:
        """Queued + resident work in job units (least-loaded routing and
        the role-switch donor choice read this; lock-free by design)."""
        n = (self.enc_q.qsize() + self.psi_in.qsize()
             + self.requeue_q.qsize() + len(self.mig_q))
        if self.scheduler is not None:
            n += len(self.scheduler.queue)
            n += len(self.scheduler.encode_q)
            n += int(self.scheduler.task is not None)
        if self.decode_stage is not None:
            n += self.decode_stage.active_count + self.psi_pd.qsize()
        return float(n)

    def _idle(self) -> bool:
        return self.load() == 0.0

    # ----------------------------------------------------- latency / faults
    def observe_latency(self, seconds: float) -> None:
        """One worked executor iteration's wall time folds into the EWMA
        the latency-aware router reads (straggler shedding)."""
        self._lat_ewma = (seconds if self._lat_ewma is None
                          else 0.3 * seconds + 0.7 * self._lat_ewma)

    def latency_ms(self) -> float:
        return 0.0 if self._lat_ewma is None else self._lat_ewma * 1e3

    def _fault_now(self) -> float:
        c = self.cluster
        return (time.perf_counter() - c._t0) - c._faults_t0

    def _fault_shim(self) -> Optional[str]:
        """Injected-fault check at the top of every executor iteration.
        Returns ``"dead"`` (executor must exit — the supervisor's sweep
        re-homes the residents), ``"stalled"`` (slept a bounded slice;
        caller re-loops), or None. Because this runs BETWEEN
        ``_step_once`` iterations, a death always lands on a quiescent
        instance state — exactly the cut the failover sweep assumes."""
        plan = self.cluster.faults
        if plan is None:
            return None
        now = self._fault_now()
        if plan.dead(self.iid, now):
            return "dead"
        stall = plan.stall_until(self.iid, now)
        if stall > now:
            time.sleep(min(stall - now, 0.05))
            return "stalled"
        return None

    def _fault_slowdown(self, elapsed: float) -> float:
        """Sleep the extra time a ``Slowdown`` multiplier adds to a worked
        iteration; returns the added seconds (bounded per iteration)."""
        plan = self.cluster.faults
        if plan is None:
            return 0.0
        m = plan.multiplier(self.iid, self._fault_now())
        if m <= 1.0:
            return 0.0
        extra = min(elapsed * (m - 1.0), 0.25)
        time.sleep(extra)
        return extra

    # --------------------------------------------------------- retirement
    def request_retire(self) -> None:
        """Supervisor-side (elastic scale-down): stop accepting; the
        executor offloads its queues, migrates decode residents
        byte-exact, and exits — mirroring the LB's ``remove_backend``
        drain semantics."""
        self.accepting = False
        self._retiring = True

    def _progress_retire(self) -> bool:
        """Executor-side retirement: offload -> migrate residents -> exit.
        Aborts (and resumes serving) if no sibling can take the work."""
        c = self.cluster
        if not self._offload():
            self._retiring = False
            self.accepting = True
            return True
        if self.scheduler is not None and self.scheduler.task is not None:
            # in-flight prefill: abandon the partial pass and re-admit the
            # request elsewhere (state is already PREFILLING)
            task, self.scheduler.task = self.scheduler.task, None
            self.prefill_stage.abandon(task)
            try:
                c._route_admission(task.req, _task_payload(task),
                                   front=True)
            except RuntimeError as e:
                c._fail(task.req, f"retirement admission failed: {e!r}")
        if self.decode_stage is not None:
            for r in c._collect_residents(self):
                c._rehome_resident(self, r, kv_ok=True)
        self.retired = True
        return True

    # ---------------------------------------------------------- switching
    def request_switch(self, new_role: str) -> None:
        """Monitor-side: stop accepting and flag the executor to drain,
        offload, and swap (writes ordered: accepting first, so an
        executor that sees the pending role also sees accepting=False)."""
        self.accepting = False
        self._pending_role = new_role

    def _progress_switch(self) -> bool:
        if not self._offload():
            # no sibling can take the queued work right now — abort; the
            # monitor re-evaluates after the cooldown-free retry
            self._pending_role = None
            self.accepting = True
            return True
        if not self._idle():
            return False                  # in-flight work still draining
        now = time.perf_counter()
        old = self.role
        c = self.cluster
        c._stats.add_role_time(old, now - self.role_since)
        self._build_role(self._pending_role)
        self.role_since = now
        self._pending_role = None
        self.cooldown_until = now + c.ccfg.switch_cooldown
        c._stats.bump("role_switches")
        c.switch_log.append((now - c._t0, self.iid, old, self.role))
        self.accepting = True
        return True

    def _channels(self, only_unserved: bool = False) -> list[tuple]:
        """Descriptors for every cluster-facing work channel:
        ``(pop, putback, req_of, route)``, where ``pop()`` returns one
        item or None. One table serves offload (route with putback on
        failure), mis-route healing (route or fail), and shutdown drain
        (collect stranded) — so a channel added later cannot be missed by
        one of the three. ``only_unserved`` keeps just the channels whose
        stage this instance's CURRENT role does not serve."""
        c = self.cluster

        def q_pop(q):
            def pop():
                try:
                    return q.get_nowait()
                except queue.Empty:
                    return None
            return pop

        def psi_pop():
            try:
                return self.psi_in.recv_nowait()
            except queue.Empty:
                return None

        def mig_pop():
            with self._mig_lock:
                return self.mig_q.popleft() if self.mig_q else None

        def mig_put(m):
            with self._mig_lock:
                self.mig_q.appendleft(m)

        first = lambda item: item[0]
        out = []
        if not only_unserved or self.encode_stage is None:
            out.append((q_pop(self.enc_q), self.enc_q.put, first,
                        c._route_encode_job))
        if not only_unserved or self.scheduler is None:
            out.append((psi_pop, lambda it: self.psi_in.send(*it), first,
                        lambda it: c._route_admission(it[0], it[1])))
            out.append((q_pop(self.requeue_q), self.requeue_q.put, first,
                        lambda it: c._route_admission(it[0], it[1],
                                                      front=True)))
        if not only_unserved and self.scheduler is not None:
            sq = self.scheduler.queue
            out.append((lambda: sq.popleft() if sq else None,
                        sq.appendleft, first,
                        lambda it: c._route_admission(it[0], it[1])))
            # lane-queued shard jobs reroute to any E-capable instance
            # (offload on switch/retire; lossless failover on death)
            eq = self.scheduler.encode_q
            out.append((lambda: eq.popleft() if eq else None,
                        eq.appendleft, first, c._route_encode_job))
        if not only_unserved or self.decode_stage is None:
            out.append((mig_pop, mig_put, lambda m: m.req,
                        c._route_migration))
        return out

    def _offload(self) -> bool:
        """Move queued-but-unstarted work to sibling instances (paper:
        offload -> migrate -> onload). Items pop ONE at a time so a
        routing failure puts exactly that item back and aborts the switch
        — nothing is ever dropped or stranded."""
        for pop, putback, _req_of, route in self._channels():
            while True:
                item = pop()
                if item is None:
                    break
                try:
                    route(item)
                except RuntimeError:
                    putback(item)
                    return False
        return True

    # ----------------------------------------------------------- executor
    def start(self) -> None:
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"I{self.iid}:{self.role}")
        self.thread.start()

    def _run(self) -> None:
        c = self.cluster
        while not c._stop.is_set() and not self.retired:
            fate = self._fault_shim()
            if fate == "dead":
                # die between iterations: quiescent state, thread exits;
                # the supervisor sweep joins us and re-homes everything
                self.alive = False
                self.accepting = False
                return
            if fate == "stalled":
                continue
            t0 = time.perf_counter()
            try:
                worked = self._step_once()
            except Exception as e:                    # noqa: BLE001
                # instance-level bug guard: fail resident decode work so
                # nothing strands behind a wedged executor, keep serving
                if self.decode_stage is not None:
                    self.decode_stage.abort_all(
                        lambda r: c._fail(r, f"instance failed: {e!r}"))
                worked = False
            if worked:
                dt = time.perf_counter() - t0
                dt += self._fault_slowdown(dt)
                self.observe_latency(dt)
            else:
                time.sleep(0.002)

    def _step_once(self) -> bool:
        if self._retiring:
            return self._progress_retire()
        worked = False
        if self._pending_role is not None:
            worked |= self._progress_switch()
        else:
            worked |= self._reroute_misrouted()
        if self._pending_role is None and self.encode_stage is not None:
            worked |= (self._feed_encode_lanes() if self._lanes
                       else self._encode_one())
        if self.decode_stage is not None:
            worked |= self._admit_migrations()
        if self.scheduler is not None:
            self._drain_requeues()
            worked |= self._scheduler_step()
        elif self.decode_stage is not None:
            worked |= self._decode_once()
        return worked

    def _reroute_misrouted(self) -> bool:
        """Self-healing: re-route items a just-finished role switch left
        behind (a router could have enqueued between the final offload
        and ``accepting`` flipping back on with a different role)."""
        worked = False
        for pop, _putback, req_of, route in self._channels(
                only_unserved=True):
            while True:
                item = pop()
                if item is None:
                    break
                worked = True
                try:
                    route(item)
                except RuntimeError as e:
                    # no instance serves the stage at all: fail loudly
                    # rather than strand (should be unreachable —
                    # switching never zeroes a stage)
                    self.cluster._fail(
                        req_of(item),
                        f"no instance serves the stage: {e!r}")
        return worked

    def _encode_one(self) -> bool:
        try:
            job = self.enc_q.get_nowait()
        except queue.Empty:
            return False
        self.cluster._run_encode_shard(self.encode_stage, *job)
        return True

    def _feed_encode_lanes(self) -> bool:
        """Lane mode: move routed shard jobs from the cluster-facing
        ``enc_q`` into the scheduler's lane queue so the packed runner
        co-schedules them with decode slots + prefill chunks (executor
        thread — the scheduler deque is private)."""
        worked = False
        while True:
            try:
                job = self.enc_q.get_nowait()
            except queue.Empty:
                return worked
            self.scheduler.submit_encode_job(job)
            worked = True

    def _admit_migrations(self) -> bool:
        """Inject inbound PD migrations into this instance's pool and hand
        them to the decode stage; pool-pressure backoff holds the head in
        place (decode retirements free blocks)."""
        c = self.cluster
        worked = False
        while True:
            with self._mig_lock:
                if not self.mig_q:
                    return worked
                m = self.mig_q[0]
            if m.req.finished:            # failed while queued (shutdown)
                with self._mig_lock:
                    self.mig_q.popleft()
                continue
            # with prefix caching, inject re-pins any prefix already
            # cached on THIS instance (m.keys travelled with the
            # migration) and commits the prompt blocks to the local index
            repinned = self.kv.inject(m.req.req_id, m.k_blocks,
                                      m.v_blocks, m.total, keys=m.keys)
            if repinned is None:
                c._stats.bump("admission_backoffs")
                return worked
            if repinned:
                c._stats.bump("prefix_cache_hits")
                c._stats.bump("prefix_tokens_reused", repinned)
            with self._mig_lock:
                self.mig_q.popleft()
            m.k_blocks = m.v_blocks = None      # release the copy
            with self.kv.lock:
                c._stats.peak(self.kv.mgr.used_blocks * self.kv.block_bytes)
            self.psi_pd.send(m)
            worked = True

    def _drain_requeues(self) -> None:
        """Move cross-instance preemption re-admits into the scheduler's
        front slots (executor thread — the scheduler deque is private)."""
        if self.requeue_q.empty():
            return
        self.scheduler.begin_requeue_batch()
        while True:
            try:
                req, mm = self.requeue_q.get_nowait()
            except queue.Empty:
                return
            self.scheduler.requeue(req, mm)

    def _scheduler_step(self) -> bool:
        c = self.cluster
        try:
            return bool(self.scheduler.step())
        except Exception as e:                        # noqa: BLE001
            if self.decode_stage is not None:
                self.decode_stage.abort_all(
                    lambda r: c._fail(r, f"scheduler failed: {e!r}"))
            return True

    def _decode_once(self) -> bool:
        c = self.cluster
        try:
            return bool(self.decode_stage.step(self.psi_pd))
        except Exception as e:                        # noqa: BLE001
            # e.g. a request whose appends alone exhaust the pool
            self.decode_stage.abort_all(
                lambda r: c._fail(r, f"decode failed: {e!r}"))
            return True

    # ------------------------------------------------------------ shutdown
    def drain(self) -> list[ServeRequest]:
        """Shutdown: abandon in-flight prefill, empty every channel;
        returns the stranded requests (the engine fails them)."""
        stranded: list[ServeRequest] = []
        for pop, _putback, req_of, _route in self._channels():
            while True:
                item = pop()
                if item is None:
                    break
                stranded.append(req_of(item))
        if self.psi_pd is not None:
            stranded.extend(h.req for h in self.psi_pd.drain())
        if self.scheduler is not None:
            stranded.extend(self.scheduler.drain())   # frees task blocks
        return stranded

    def __repr__(self) -> str:  # pragma: no cover
        return (f"InstanceWorker(id={self.iid}, role={self.role}, "
                f"load={self.load():.0f}, accepting={self.accepting})")


class ClusterEngine(EngineBase):
    """N real engine instances behind one submit()/result()/stream() API.

    ``cluster`` is a :class:`ClusterConfig` or a bare spec string
    (``"2E1P1D"``). Requires a paged-capable config (the dense baseline
    stays single-pipeline in ``EPDEngine``)."""

    def __init__(self, cfg: ArchConfig, params: Any, engine: EngineConfig,
                 cluster: Union[ClusterConfig, str] = "1EPD", *,
                 hw: HardwareProfile = A100_80G,
                 faults: Optional[FaultPlan] = None):
        if isinstance(cluster, str):
            cluster = ClusterConfig(spec=cluster)
        super().__init__(cfg, params, engine)
        if not self.paged:
            raise ValueError(
                f"ClusterEngine requires a paged-capable config "
                f"(family={cfg.family!r}, mode={engine.mode!r}); use "
                f"EPDEngine for the dense baseline")
        if cluster.assign_policy not in _POLICIES:
            raise ValueError(f"unknown assign policy "
                             f"{cluster.assign_policy!r}")
        self.ccfg = cluster
        self.kit = PagedJitKit(self.model, cfg, backend=self.backend)
        # IRP shard planning is cluster-level: shards of one request may
        # encode on different E instances (the simulator does the same)
        self.encode_planner = EncodeStage(self.model, cfg, params,
                                          engine.n_encode_workers,
                                          kit=self.kit)
        roles = ClusterSpec(cluster.spec).roles()
        self._t0 = time.perf_counter()
        # fault injection: plan times are relative to _faults_t0 (0 = the
        # engine's birth; set_fault_plan rebases to "now")
        self.faults = faults
        self._faults_t0 = 0.0
        self._started = False
        self._next_iid = len(roles)     # elastic adds never reuse an iid
        self.scale_log: list[tuple[float, str, int, str]] = []
        self._scale_cooldown_until = 0.0
        self.instances = [InstanceWorker(i, r, self)
                          for i, r in enumerate(roles)]
        for letter in "PD":
            if not self._serving(letter):
                raise ValueError(
                    f"cluster spec {cluster.spec!r} has no {letter}-capable "
                    f"instance")
        self._assigners = {letter: Assigner(_POLICIES[cluster.assign_policy])
                           for letter in "EPD"}
        self.load_estimator = LoadEstimator(cfg, hw)
        self.switch_log: list[tuple[float, int, str, str]] = []
        self._monitor_thread: Optional[threading.Thread] = None

    def set_fault_plan(self, plan: Optional[FaultPlan]) -> None:
        """Install (or clear) a fault plan on a LIVE engine. Plan times
        are relative to now — ``Death(iid, at=0.0)`` kills instance
        ``iid`` at its executor's next iteration — so tests can reach a
        steady state first, then inject."""
        self._faults_t0 = time.perf_counter() - self._t0
        self.faults = plan

    # ------------------------------------------------------------- routing
    def _serving(self, letter: str) -> list[InstanceWorker]:
        return [i for i in self.instances
                if i.serves(letter) and i.alive and not i.retired]

    def _pick(self, letter: str) -> InstanceWorker:
        insts = self._serving(letter)
        if not insts:
            raise RuntimeError(f"no {letter}-capable instance")
        return insts[self._assigners[letter].pick(insts)]

    def _route_admission(self, req: ServeRequest, mm_tokens,
                         front: bool = False) -> None:
        inst = self._pick("P")
        if front:
            inst.requeue_q.put((req, mm_tokens))
        else:
            inst.psi_in.send(req, mm_tokens)

    def _route_encode_job(self, job: tuple) -> None:
        self._pick("E").enc_q.put(job)

    def _route_migration(self, payload: MigratedPrefill) -> None:
        inst = self._pick("D")
        with inst._mig_lock:
            inst.mig_q.append(payload)

    # -------------------------------------------------------- engine hooks
    def _has_encoder(self) -> bool:
        return (self.kit.encode_fn is not None
                and bool(self._serving("E")))

    def _check_mm(self, req: ServeRequest) -> None:
        if self.kit.encode_fn is not None and not self._serving("E"):
            raise ValueError(
                f"request {req.req_id}: multimodal payload but cluster "
                f"spec {self.ccfg.spec!r} has no E-capable instance")

    def _dispatch_prefill(self, req: ServeRequest, mm_tokens) -> None:
        try:
            self._route_admission(req, mm_tokens)
        except RuntimeError as e:
            self._fail(req, f"admission routing failed: {e!r}")

    def _overlap_capable(self) -> bool:
        # every P-capable instance runs the chunked-prefill Scheduler,
        # which gates streamed admissions on the encoded watermark; the
        # shared ψ_EP assembler keeps stream state across E-instance
        # deaths (failover replays only the still-queued shard jobs)
        return True

    def _dispatch_encode(self, req: ServeRequest,
                         key: Optional[str]) -> None:
        shards = self.encode_planner.plan_shards(req)
        stream = self._open_overlap_stream(req, len(shards))
        try:
            for sid, idx in enumerate(shards):
                self._route_encode_job((req, sid, len(shards), idx, key))
        except RuntimeError as e:
            self._encode_job_failed(req, key,
                                    f"encode routing failed: {e!r}")
            return
        if stream is not None:
            self._start_streaming_prefill(req, stream)

    def _release_blocks(self, req: ServeRequest) -> None:
        # at most one instance pool holds this request's blocks; free is
        # a no-op everywhere else
        for inst in self.instances:
            kv = inst.kv
            if kv is not None:
                with kv.lock:
                    kv.mgr.free(req.req_id)

    def _requeue(self, req: ServeRequest, mm_tokens) -> None:
        """Preemption: re-admit at the FRONT of a P instance's queue (the
        deterministic replay reproduces the same prefix)."""
        req.advance(RequestState.PREFILLING)
        try:
            self._route_admission(req, mm_tokens, front=True)
        except RuntimeError as e:
            self._fail(req, f"requeue routing failed: {e!r}")

    def _on_submit(self, req: ServeRequest) -> None:
        from repro.serving.api import sim_request_of
        now = time.perf_counter() - self._t0
        self.load_estimator.observe(sim_request_of(self.cfg, req, now), now)

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        self._started = True
        for inst in self.instances:
            inst.start()
            self._threads.append(inst.thread)
        # the supervisor always runs: dead-instance failover must work on
        # every topology, not only when role switching or elastic scaling
        # is configured (those duties are gated on their config flags)
        self._monitor_thread = threading.Thread(
            target=self._supervisor_loop, daemon=True, name="supervisor")
        self._monitor_thread.start()
        self._threads.append(self._monitor_thread)

    def stop(self, timeout: float = 5.0) -> None:
        """Signal every executor + the monitor, join them, then drain all
        channels and fail resident requests — including mid-switch state
        (a pending switch simply never completes; its queues drain like
        any other instance's)."""
        self._join_threads(timeout)
        error = "engine stopped before the request completed"
        self.psi_ep.drain()
        now = time.perf_counter()
        for inst in self.instances:
            for req in inst.drain():
                self._fail(req, error)
            self._stats.add_role_time(inst.role, now - inst.role_since)
            inst.role_since = now
        self._fail_residents(error)

    # ----------------------------------------------------------- failover
    def _collect_residents(self, inst: InstanceWorker) -> list[dict]:
        """Export every decode resident of ``inst``: ψ_PD-parked handoffs
        (admitted to the pool, not yet slotted) + live decode slots. Only
        safe on the instance's executor thread, or after that thread has
        exited (death / retirement) — the structures are executor-private."""
        residents: list[dict] = []
        if inst.psi_pd is not None:
            for h in inst.psi_pd.drain():
                residents.append({
                    "req": h.req, "mm_tokens": h.mm_tokens,
                    "last_tok": h.first_tok, "position": h.total,
                    "x_pending": (h.x_last if h.first_tok is None else None)})
        if inst.decode_stage is not None:
            residents.extend(inst.decode_stage.evacuate())
        return residents

    def _rehome_resident(self, src: InstanceWorker, r: dict, *,
                         kv_ok: bool) -> None:
        """Move one decode resident off ``src``: byte-exact ψ_PD
        extract/inject migration when the KV is reachable (greedy streams
        stay bit-identical), else preemption-replay from the prompt."""
        req = r["req"]
        if req.finished:
            with src.kv.lock:
                src.kv.mgr.free(req.req_id)
            return
        if kv_ok:
            try:
                k, v = src.kv.extract(req.req_id)
                payload = MigratedPrefill(
                    req=req, first_tok=r["last_tok"], total=r["position"],
                    mm_tokens=r["mm_tokens"], k_blocks=k, v_blocks=v,
                    keys=None, x_last=r["x_pending"])
                self._route_migration(payload)
                self._stats.bump("fault_failovers")
                return
            except RuntimeError:
                pass     # no surviving D sibling: fall through to replay
        with src.kv.lock:
            src.kv.mgr.free(req.req_id)
        req.reset_generation()
        self._stats.bump("preemptions")
        self._stats.bump("fault_replays")
        self._requeue(req, r["mm_tokens"])    # fails the req if unroutable

    def _failover_instance(self, inst: InstanceWorker) -> None:
        """Re-home everything a dead instance held (supervisor thread;
        the executor has exited, so its channels/slots have one toucher).
        Queued work reroutes losslessly; in-flight prefill re-admits from
        the prompt; decode residents migrate byte-exact when the dead
        pool is still reachable, else replay."""
        inst.failed_over = True
        self._stats.bump("instance_deaths")
        death = (self.faults.death_for(inst.iid)
                 if self.faults is not None else None)
        kv_ok = death.kv_reachable if death is not None else True
        for pop, _putback, req_of, route in inst._channels():
            while True:
                item = pop()
                if item is None:
                    break
                try:
                    route(item)
                    self._stats.bump("jobs_rerouted")
                except RuntimeError as e:
                    self._fail(req_of(item),
                               f"no surviving instance: {e!r}")
        sched = inst.scheduler
        if sched is not None and sched.task is not None:
            task, sched.task = sched.task, None
            inst.prefill_stage.abandon(task)
            try:
                self._route_admission(task.req, _task_payload(task),
                                      front=True)
                self._stats.bump("jobs_rerouted")
            except RuntimeError as e:
                self._fail(task.req, f"no surviving instance: {e!r}")
        if inst.decode_stage is not None:
            for r in self._collect_residents(inst):
                self._rehome_resident(inst, r, kv_ok=kv_ok)

    def _sweep_dead_instances(self) -> None:
        for inst in list(self.instances):
            if inst.alive or inst.failed_over:
                continue
            t = inst.thread
            if t is not None and t.is_alive():
                t.join(timeout=1.0)
                if t.is_alive():
                    continue              # executor still exiting: retry
            self._failover_instance(inst)

    def _reap_retired(self) -> None:
        """Drop instances whose elastic retirement completed (their
        executor migrated everything out and exited)."""
        for inst in list(self.instances):
            if not inst.retired:
                continue
            t = inst.thread
            if t is not None and t.is_alive():
                continue                  # exiting; reap next tick
            # atomic list swap: router threads iterating the old list are
            # unaffected (the retired instance routes nothing anyway)
            self.instances = [i for i in self.instances if i is not inst]
            self._stats.bump("scale_downs")
            self.scale_log.append((time.perf_counter() - self._t0, "down",
                                   inst.iid, inst.role))

    # ------------------------------------------------------ elastic scaling
    def add_instance(self, role: str) -> InstanceWorker:
        """Elastic scale-up (ElasticMM-style): spawn a new instance of
        ``role`` and start its executor if the engine is running."""
        if role not in ("E", "P", "D", "EP", "EPD"):
            raise ValueError(f"unknown role {role!r}")
        inst = InstanceWorker(self._next_iid, role, self)
        self._next_iid += 1
        self.instances = self.instances + [inst]
        if self._started:
            inst.start()
            self._threads.append(inst.thread)
        self._stats.bump("scale_ups")
        self.scale_log.append((time.perf_counter() - self._t0, "up",
                               inst.iid, role))
        return inst

    def remove_instance(self, iid: int) -> bool:
        """Elastic scale-down: request a drain-and-retire of instance
        ``iid`` (offload queues, migrate decode residents byte-exact,
        executor exits; the supervisor reaps it). Refuses — returning
        False — when the instance is dead/retiring or is the last server
        of any stage letter it serves."""
        inst = next((i for i in self.instances if i.iid == iid), None)
        if (inst is None or not inst.alive or inst.retired
                or inst._retiring):
            return False
        for letter in "EPD":
            if inst.serves(letter) and len(self._serving(letter)) <= 1:
                return False
        inst.request_retire()
        return True

    def autoscale_once(self) -> Optional[tuple[str, str]]:
        """One elastic-scaling evaluation (public so tests and benchmarks
        drive it without the timer): consult the LoadEstimator's per-stage
        utilization and add/remove ONE instance, under cooldown and
        min/max fleet bounds. Returns ``(op, letter)`` or None."""
        now = time.perf_counter() - self._t0
        if now < self._scale_cooldown_until:
            return None
        live = [i for i in self.instances
                if i.alive and not i.retired and not i._retiring]
        counts = {s: sum(1 for i in live if i.serves(s)) for s in "EPD"}
        hint = self.load_estimator.suggest_scale(
            counts, up=self.ccfg.scale_up_util,
            down=self.ccfg.scale_down_util)
        if hint is None:
            return None
        op, letter = hint
        if op == "up":
            if len(live) >= self.ccfg.max_instances:
                return None
            self.add_instance(letter)
            self._scale_cooldown_until = now + self.ccfg.scale_cooldown
            return ("up", letter)
        if len(live) <= self.ccfg.min_instances:
            return None
        cands = [i for i in live if i.role == letter]
        if not cands:
            return None                   # only multi-letter servers left
        victim = min(cands, key=lambda i: i.load())
        if self.remove_instance(victim.iid):
            self._scale_cooldown_until = now + self.ccfg.scale_cooldown
            return ("down", letter)
        return None

    # ----------------------------------------------------------- supervisor
    def _supervisor_loop(self) -> None:
        while not self._stop.wait(self.ccfg.monitor_interval):
            try:
                self.supervise_once()
            except Exception:                         # noqa: BLE001
                # a broken evaluation skips this tick, never dies — but
                # the failure must be diagnosable (a silently dead
                # supervisor = failover/switching silently off)
                self._stats.bump("monitor_errors")

    def supervise_once(self) -> None:
        """One supervisor tick (public so tests drive it deterministically):
        dead-instance failover sweep, retired-instance reaping, then the
        config-gated duties — elastic scaling and role switching."""
        self._sweep_dead_instances()
        self._reap_retired()
        if self.ccfg.elastic:
            self.autoscale_once()
        if self.ccfg.role_switch:
            self.monitor_once()

    def monitor_once(self) -> Optional[tuple[int, str, str]]:
        """One role-switch evaluation (public so tests and benchmarks can
        drive it deterministically without the timer thread).

        Compares the LoadEstimator's suggested allocation over the
        single-letter instances with the current one and re-roles ONE
        idle, cooled-down donor toward the hottest deficit. Returns
        ``(instance_id, old_role, new_role)`` when a switch was
        requested, else None."""
        if any(i._pending_role is not None for i in self.instances):
            return None                       # one switch in flight at a time
        singles = [i for i in self.instances
                   if len(i.role) == 1 and i.alive and not i.retired
                   and not i._retiring]
        if len(singles) < 2:
            return None
        demand = self.load_estimator.stage_demand()
        if not any(v > 0.0 for v in demand.values()):
            return None                       # nothing observed yet
        target = self.load_estimator.suggest_allocation(len(singles))
        cur = {"E": 0, "P": 0, "D": 0}
        for i in singles:
            cur[i.role] += 1
        deficit = {s: target.get(s, 0) - cur[s] for s in "EPD"}
        hot = max((s for s in "EPD" if deficit[s] > 0),
                  key=lambda s: (deficit[s], demand[s]), default=None)
        if hot is None:
            return None
        # donors: overloaded letters that keep >= 1 serving instance after
        # losing one (a stage never drops to zero)
        donors = [s for s in "EPD"
                  if s != hot and deficit[s] < 0 and cur[s] >= 1
                  and len(self._serving(s)) >= 2]
        if not donors:
            return None
        cold = min(donors, key=lambda s: demand[s] / max(cur[s], 1))
        now = time.perf_counter()
        ready = [i for i in singles
                 if i.role == cold and i.accepting
                 and i.cooldown_until <= now]
        if not ready:
            return None
        donor = min(ready, key=lambda i: i.load())    # prefer idle
        donor.request_switch(hot)
        return (donor.iid, cold, hot)

    # ------------------------------------------------------------- queries
    def current_roles(self) -> list[str]:
        """Live role of every serving instance (changes as the monitor
        re-roles and as instances die / scale in and out)."""
        return [i.role for i in self.instances
                if i.alive and not i.retired]

    def queue_depth(self) -> int:
        return int(sum(i.load() for i in self.instances
                       if i.alive and not i.retired))

    def kv_block_counts(self) -> tuple[int, int]:
        free = total = 0
        for inst in self.instances:
            if not inst.alive or inst.retired:
                continue             # a dead pool serves no new requests
            kv = inst.kv
            if kv is not None:
                with kv.lock:
                    free += kv.mgr.free_blocks
                total += self.ecfg.kv_blocks
        return (free, total)

    def instance_states(self) -> dict[str, int]:
        alive = sum(1 for i in self.instances if i.alive and not i.retired)
        return {"alive": alive,
                "dead": sum(1 for i in self.instances if not i.alive),
                "retiring": sum(1 for i in self.instances
                                if i._retiring and not i.retired)}
