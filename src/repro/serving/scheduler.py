"""Unified continuous-batching scheduler for the paged serving path.

One iteration loop co-schedules **chunked prefill** and the batched
decode step under a per-iteration token budget (paper §4: the SLO story
needs decode to never stall behind a long prefill; RServe/ElasticMM in
PAPERS.md make the same case for co-scheduling stage work):

  * decode runs first every iteration — its ONE jitted batched step is
    never queued behind prefill compute, so TPOT stays flat while a long
    prompt trickles in chunk-by-chunk;
  * the leftover budget (``EngineConfig.step_token_budget`` minus the
    decode slots just stepped) is spent on prefill chunks of the task at
    the head of the admission queue; when decode is idle, at least one
    chunk always runs (guaranteed progress);
  * admission is a real FIFO queue with pool-pressure backoff: if the
    head request's blocks don't fit, the scheduler simply keeps it at
    the head (later arrivals cannot starve it) and lets decode
    retirements free blocks — replacing the old head-of-line
    ``time.sleep(0.01)`` busy-wait thread;
  * preempted requests re-enter at the FRONT of the queue
    (preempt-aware: they already held capacity once and replay
    deterministically, so re-admitting them first minimizes wasted
    work).

The scheduler is single-threaded by construction — the engine drives it
from one worker — so prefill/decode interleaving is deterministic given
arrival order, and every stage method it calls stays unit-testable
without threads (the stages are duck-typed; tests drive the scheduler
with stubs).
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.serving.stages import PagedDecodeStage, PagedPrefillStage, ServeStats
from repro.serving.transfer import PrefillProgress, PsiEP, PsiPD
from repro.serving.types import EngineConfig, RequestState, ServeRequest

__all__ = ["Scheduler"]


class Scheduler:
    """Iteration-level co-scheduler over the paged P and D stages.

    Two execution paths share the admission/budget policy:

      * ``runner`` set (the default engines): the iteration plan — decode
        slots + this iteration's prefill chunks — executes as ONE
        token-packed jitted forward (``serving.runner.ModelRunner``);
      * ``runner`` None: the historical two-program path (batched decode
        step, then one chunk program per chunk), kept as the parity
        oracle (``EngineConfig.runner = "two_program"``) and for
        duck-typed stage stubs in the policy tests.
    """

    def __init__(self, ecfg: EngineConfig, prefill: PagedPrefillStage,
                 decode: PagedDecodeStage, psi_ep: PsiEP, psi_pd: PsiPD,
                 stats: ServeStats, stop_event: threading.Event,
                 on_fail: Callable[[ServeRequest, str], None], *,
                 runner=None):
        self.ecfg = ecfg
        self.prefill = prefill
        self.decode = decode
        self.runner = runner
        self.psi_ep = psi_ep
        self.psi_pd = psi_pd
        self.stats = stats
        self._stop = stop_event
        self.on_fail = on_fail
        # FIFO admission queue of prefill-ready (req, mm_tokens);
        # preemption re-admits at the front — ``_front`` preserves the
        # relative order of several victims preempted in one decode step
        # (a bare appendleft would reverse them into LIFO)
        self.queue: deque = deque()
        self._front = 0
        self.task: Optional[PrefillProgress] = None
        # packed encode lanes: ψ_EP shard jobs routed INTO the iteration
        # plan (instead of E worker threads) — planned under the leftover
        # budget each iteration and executed inside the packed program.
        # A deque (not a Queue): producers append, only the scheduler
        # thread pops, and a budget overrun can push a job back to the
        # front without reordering.
        self.encode_q: deque = deque()
        # engine hook: fail a lane shard's request AND its mm-dedup
        # followers (falls back to plain on_fail when unwired)
        self.on_encode_fail: Optional[Callable] = None
        # effective chunk (block-aligned by the stage) and budget; the
        # budget is clamped so one full decode round plus one chunk always
        # fits — a smaller value would silently starve prefill whenever
        # any decode slot is active (the exact stall this loop removes)
        self.chunk = max(prefill.chunk, 1)     # unchunked counts as 1 slot
        floor = ecfg.decode_batch + self.chunk
        self.budget = max(ecfg.step_token_budget or floor, floor)

    # ------------------------------------------------------------ admission
    def requeue(self, req: ServeRequest, mm_tokens: Any) -> None:
        """Preemption path: re-admit at the FRONT of the FIFO (victims
        preempted in the same step keep their relative order)."""
        self.queue.insert(self._front, (req, mm_tokens))
        self._front += 1

    def submit_encode_job(self, job: tuple) -> None:
        """Queue one IRP shard ``(req, sid, n_shards, idx, key)`` for the
        packed encode lanes (EngineConfig.encode_lanes)."""
        self.encode_q.append(job)

    def begin_requeue_batch(self) -> None:
        """Reset the front-insertion cursor before a batch of ``requeue``
        calls made OUTSIDE ``step()`` (a cluster instance draining its
        cross-instance requeue channel between iterations); without this
        the cursor still points past the previous step's insertions."""
        self._front = 0

    def _drain_arrivals(self) -> None:
        while True:
            try:
                self.queue.append(self.psi_ep.recv_nowait())
            except queue.Empty:
                return

    def _try_admit(self) -> Optional[PrefillProgress]:
        while self.queue:
            req, mm_tokens = self.queue[0]
            if req.finished:        # failed while queued (e.g. IRP sibling)
                self.queue.popleft()
                continue
            try:
                task = self.prefill.start(req, mm_tokens)
            except Exception as e:                    # noqa: BLE001
                # a request that cannot even be admitted must not wedge
                # the queue head forever
                self.queue.popleft()
                self.on_fail(req, f"prefill admission failed: {e!r}")
                continue
            if task is None:
                # pool-pressure backoff: hold the head in place — FIFO
                # order means later arrivals cannot starve it; decode
                # retirements will free blocks
                self.stats.bump("admission_backoffs")
                return None
            self.queue.popleft()
            return task
        return None

    # ------------------------------------------------------------ iteration
    def step(self) -> bool:
        """One scheduler iteration; returns False when fully idle."""
        self._drain_arrivals()
        self._front = 0      # this step's preemption-requeue insertions
        if self.runner is not None:
            return self._step_packed()
        # decode first: the batched step is never queued behind prefill
        try:
            stepped = self.decode.step(self.psi_pd)
        except Exception as e:                        # noqa: BLE001
            # e.g. a request whose appends alone exhaust the pool: fail
            # the in-flight requests instead of stranding them, then keep
            # serving new arrivals
            self.decode.abort_all(
                lambda r: self.on_fail(r, f"decode failed: {e!r}"))
            stepped = 0
        spent = int(stepped)
        chunks = 0
        # chunked prefill under the leftover budget; when decode is idle
        # at least one chunk runs regardless (guaranteed progress)
        while not self._stop.is_set():
            if self.task is None:
                self.task = self._try_admit()
            if self.task is None or self._drop_aborted_task():
                break
            if (spent + self.chunk > self.budget
                    and not (stepped == 0 and chunks == 0)):
                break
            if self._stream_gate(self.task):
                break    # watermark gate: encode hasn't caught up yet
            spent += self.chunk
            chunks += 1
            self._advance_task()
        return bool(stepped or chunks)

    def _next_span(self, task: PrefillProgress) -> tuple[int, int]:
        """The prompt span the task's NEXT prefill call will cover."""
        if self.runner is not None:
            return task.n_done, task.n_done + self.runner.next_chunk_len(task)
        S = task.total
        chunk = self.prefill.chunk
        if self._whole_path(task):
            return task.n_done, S
        return task.n_done, min(task.n_done + chunk, S)

    def _whole_path(self, task: PrefillProgress) -> bool:
        """Whether the two-program path will run the UNCHUNKED prefill
        program for this task (mirrors ``run_chunk``'s dispatch)."""
        chunk = self.prefill.chunk
        return chunk <= 0 or (task.n_done == 0 and task.total <= chunk)

    def _stream_gate(self, task: PrefillProgress) -> bool:
        """Encode–prefill overlap: True when the task's next span covers
        a placeholder whose shard has not been published yet (the chunk
        must wait at the encoded watermark). When the span IS ready, the
        published shard tokens are pulled into the embedded prompt and
        the early-chunk counters move."""
        st = getattr(task, "stream", None)
        if st is None or task.mm_tokens is not None:
            return False
        t0, t1 = self._next_span(task)
        if not st.span_ready(t0, t1):
            return True
        # sync AFTER the span check: a shard published between an earlier
        # fill and the check must land in x before the chunk slices it
        task.sync_stream()
        if task.mm_tokens is None:
            if self.runner is None and self._whole_path(task):
                # the unchunked program re-embeds from the merged token
                # set inside its jit — a partial stream can't feed it
                # (overlap is a documented no-op for single-chunk
                # prompts); wait for the full merge
                return True
            self.stats.bump("overlap_chunks_early")
            self.stats.set_hwm("overlap_watermark_hwm",
                               st.watermark(task.total))
        return False

    def _drop_aborted_task(self) -> bool:
        """Abandon the in-flight prefill task if its request was aborted
        from outside the scheduler thread (client disconnect). The
        abandon here — on the scheduler's own thread, between iterations
        — is what frees the task's blocks: an external free could land
        mid-iteration while a planned block table is in flight."""
        if self.task is None or not self.task.req.finished:
            return False
        self.prefill.abandon(self.task)
        self.task = None
        return True

    def _advance_task(self) -> None:
        task = self.task
        try:
            done = self.prefill.run_chunk(task)
        except Exception as e:                        # noqa: BLE001
            self.task = None
            self.on_fail(task.req, f"prefill failed: {e!r}")
            return
        if done:
            self.task = None
            self._to_decode(task)

    def _to_decode(self, task: PrefillProgress) -> None:
        """Hand a completed prefill to decode — unless the request was
        aborted mid-chunk, in which case its blocks are released here
        instead (the KV content is still committed to the prefix index
        first when caching is on: a fully-prefilled prompt's blocks are
        valid for reuse regardless of the abort)."""
        self._commit_cache(task)
        try:
            task.req.advance(RequestState.DECODING)
        except ValueError:
            if not task.req.finished:
                raise
            self.prefill.abandon(task)
            return
        self.psi_pd.send(task)

    def _commit_cache(self, task: PrefillProgress) -> None:
        """Publish a completed prefill's blocks into the prefix index
        (no-op for duck-typed stage stubs and with the cache off)."""
        commit = getattr(self.prefill, "commit_cache", None)
        if commit is not None:
            commit(task)

    # ------------------------------------------------------- packed runner
    def _step_packed(self) -> bool:
        """One iteration through the token-packed ModelRunner: plan the
        decode slots + prefill chunks under the token budget, then run
        the whole plan as ONE jitted forward."""
        runner = self.runner
        try:
            active = runner._prepare(self.psi_pd)
        except Exception as e:                        # noqa: BLE001
            # e.g. a request whose appends alone exhaust the pool
            runner.abort_all(
                lambda r: self.on_fail(r, f"decode failed: {e!r}"))
            active = np.zeros(len(runner._slots), dtype=bool)
        n_dec = int(active.sum())
        spent = n_dec
        chunks = []
        handed = 0           # fully-cached direct-to-decode handoffs
        planned_tokens = 0
        # the same budget policy as the two-program path; additionally the
        # packed prefill region is capped at the runner's largest bucket
        while not self._stop.is_set():
            if self.task is None:
                self.task = self._try_admit()
            if self.task is None or self._drop_aborted_task():
                break
            if self.task.done and self.task.first_tok is None:
                # fully-cached prompt (prefix cache): ZERO prefill rows —
                # commit (clears the in-flight claim), hand straight to
                # decode; the pending-x row there samples the first
                # token. Costs no budget; each pass consumes a queue
                # entry, so the admission loop still terminates. With a
                # live stream the token-less handoff waits for the full
                # merge (its x_last row must be final).
                task = self.task
                if getattr(task, "stream", None) is not None:
                    task.sync_stream()
                    if task.mm_tokens is None:
                        break
                self.task = None
                self.stats.bump("prefill_completions")
                self._to_decode(task)
                handed += 1
                continue
            n_new = runner.next_chunk_len(self.task)
            over = (spent + self.chunk > self.budget
                    or planned_tokens + n_new > runner.max_prefill_tokens)
            if over and not (n_dec == 0 and not chunks):
                break
            if self._stream_gate(self.task):
                break    # watermark gate: encode hasn't caught up yet
            chunks.append(runner.plan_chunk(self.task))
            planned_tokens += n_new
            spent += self.chunk
            if self.task.done:
                self.task = None     # fully planned; completes in execute
        # packed encode lanes: spend the leftover budget on queued IRP
        # shards (group rows in the same program). When the iteration is
        # otherwise empty — e.g. the head task is watermark-blocked on
        # these very shards — at least one job always runs (guaranteed
        # progress, no deadlock).
        enc_works: list = []
        planned_groups = 0
        while self.encode_q and not self._stop.is_set():
            if (spent >= self.budget
                    and (n_dec or chunks or handed or enc_works)):
                break
            job = self.encode_q.popleft()
            if job[0].finished:      # aborted while queued
                continue
            w = runner.plan_encode(job)
            if (enc_works and planned_groups + len(w.groups)
                    > runner.max_encode_groups):
                self.encode_q.appendleft(job)   # doesn't fit this bucket
                break
            enc_works.append(w)
            planned_groups += len(w.groups)
            spent += w.tokens_cost
        try:
            stepped, finished = runner.execute(active, chunks, enc_works)
        except Exception as e:                        # noqa: BLE001
            # the packed program is one blast radius: fail every planned
            # prefill task, encode shard, and decode slot, then keep
            # serving
            failed = {id(c.task): c.task for c in chunks}
            for task in failed.values():
                if self.task is task:
                    self.task = None
                self.on_fail(task.req, f"packed step failed: {e!r}")
            for w in enc_works:
                self._fail_encode(w.req, w.key, f"packed step failed: {e!r}")
            runner.abort_all(
                lambda r: self.on_fail(r, f"packed step failed: {e!r}"))
            return True
        for task in finished:
            self._to_decode(task)
        return bool(stepped or chunks or handed or enc_works)

    def _fail_encode(self, req: ServeRequest, key, error: str) -> None:
        if self.on_encode_fail is not None:
            self.on_encode_fail(req, key, error)
        else:
            self.on_fail(req, error)

    # ------------------------------------------------------------- shutdown
    def drain(self) -> list[ServeRequest]:
        """Shutdown: abandon the in-flight task and empty the admission
        queue; returns the stranded requests (the engine fails them)."""
        stranded = []
        if self.task is not None:
            self.prefill.abandon(self.task)
            stranded.append(self.task.req)
            self.task = None
        while self.queue:
            req, _ = self.queue.popleft()
            stranded.append(req)
        while self.encode_q:
            # lane shards of one request appear once per shard; the
            # engine's fail path is idempotent
            stranded.append(self.encode_q.popleft()[0])
        return stranded
