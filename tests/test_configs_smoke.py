"""Per-architecture smoke tests (brief requirement f).

Every assigned architecture instantiates a REDUCED variant (2 layers,
d_model<=512, <=4 experts) and runs one forward/train step + one
prefill+decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, PAPER_LMMS, get_config
from repro.configs.base import InputShape
from repro.models import build_model, make_concrete_batch

TRAIN = InputShape("smoke_train", 64, 2, "train")
PREFILL = InputShape("smoke_prefill", 64, 2, "prefill")


def _check_reduced(cfg):
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    _check_reduced(cfg)
    model = build_model(cfg)
    params = model.init(rng_key)
    batch = make_concrete_batch(cfg, TRAIN, rng_key)
    loss, metrics = model.loss_fn(params, batch=batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: model.loss_fn(p, batch=batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_prefill_decode(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    batch = make_concrete_batch(cfg, PREFILL, rng_key)
    kw = {} if cfg.family == "ssm" else {"max_len": 80}
    logits, cache = model.prefill(params, batch=batch, **kw)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params,
                                        batch={"token": tok, "cache": cache})
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", PAPER_LMMS)
def test_paper_lmm_configs_register(arch):
    cfg = get_config(arch)
    assert cfg.modality is not None
    assert cfg.param_count() > 1e9
