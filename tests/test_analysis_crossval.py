"""Cross-validate the three views of the lock hierarchy.

The repo carries the acquisition order in three places that must agree:

1. the DECLARED registry (``repro.analysis.hierarchy.EDGES``),
2. the STATIC edge set the concurrency pass extracts from the source,
3. the WITNESSED edges from real engine/cluster/gateway executions
   under ``REPRO_LOCK_SANITIZER=1``
   (``tests/fixtures/lock_order_edges.json`` — regeneration command in
   the fixture's ``_note``).

Drift in any direction is a bug: a witnessed edge the static pass
cannot see means the analyzer lost coverage; a declared edge with no
static witness is a stale registry entry; a cycle anywhere is a
deadlock waiting for the right interleaving.
"""
import json
from pathlib import Path

from repro.analysis import hierarchy
from repro.analysis.concurrency import static_edge_names

REPO = Path(__file__).resolve().parents[1]
FIXTURE = Path(__file__).parent / "fixtures" / "lock_order_edges.json"


def _witnessed():
    data = json.loads(FIXTURE.read_text())
    return {tuple(e) for e in data["edges"]}, data


def _static():
    return static_edge_names([REPO / "src", REPO / "tests"], REPO)


def test_fixture_run_was_clean_and_meaningful():
    witnessed, data = _witnessed()
    assert data["violations"] == []
    assert data["acquisitions"] > 1000, \
        "fixture run barely exercised the engines"
    assert witnessed, "no named edges witnessed — site table broken?"
    # the documented engine edge must actually be exercised at runtime
    assert ("engine.done_cv", "request.cv") in witnessed


def test_witnessed_edges_are_statically_known():
    """Every runtime-observed edge must be visible to the static pass
    or declared: an invisible edge means the analyzer would miss the
    inverse-order bug too."""
    witnessed, _ = _witnessed()
    known = _static() | hierarchy.declared_edge_set()
    assert witnessed <= known, \
        f"runtime edges unknown to the static pass: {witnessed - known}"


def test_declared_edges_have_static_witnesses():
    """The registry documents real code, not folklore: every declared
    edge must be observed somewhere in the source."""
    static = _static()
    stale = hierarchy.declared_edge_set() - static
    assert not stale, f"declared edges with no static witness: {stale}"


def test_combined_graph_is_acyclic():
    """Declared + witnessed edges together must stay a DAG."""
    witnessed, _ = _witnessed()
    graph = {}
    for a, b in witnessed | hierarchy.declared_edge_set():
        graph.setdefault(a, set()).add(b)

    WHITE, GRAY, BLACK = 0, 1, 2
    color = {n: WHITE for n in
             set(graph) | {b for bs in graph.values() for b in bs}}

    def dfs(n):
        color[n] = GRAY
        for m in graph.get(n, ()):
            if color[m] == GRAY:
                raise AssertionError(f"cycle through {n} -> {m}")
            if color[m] == WHITE:
                dfs(m)
        color[n] = BLACK

    for n in list(color):
        if color[n] == WHITE:
            dfs(n)
