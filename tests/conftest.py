# NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
# smoke tests and benches must see the 1 real device; only the dry-run
# (repro.launch.dryrun, run as its own process) forces 512 host devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
