# NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
# smoke tests and benches must see the 1 real device; only the dry-run
# (repro.launch.dryrun, run as its own process) forces 512 host devices.
import os

import jax
import pytest

# Opt-in runtime lock-order sanitizer (REPRO_LOCK_SANITIZER=1): patch
# threading BEFORE test modules import repro.serving so every engine
# lock/condvar is created tracked. Installing after `import jax` keeps
# jax/stdlib internals unpatched (their locks predate the patch).
_SANITIZER = None
if os.environ.get("REPRO_LOCK_SANITIZER", "") not in ("", "0"):
    from repro.analysis import lock_sanitizer

    _SANITIZER = lock_sanitizer.install()


@pytest.fixture(scope="session", autouse=True)
def _lock_sanitizer_session():
    """Dump the witnessed acquisition graph and fail the session on any
    hierarchy violation (teardown errors surface as pytest errors)."""
    yield
    if _SANITIZER is None:
        return
    dump = os.environ.get("REPRO_LOCK_SANITIZER_DUMP")
    if dump:
        _SANITIZER.dump(dump)
    assert not _SANITIZER.violations, _SANITIZER.report()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
