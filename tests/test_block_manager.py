"""Block-manager unit + property tests (hypothesis).

Invariants: used + free == capacity; a block has at most one owner; free()
returns exactly what allocate()/append() handed out; OutOfBlocks precisely
when demand exceeds free blocks.
"""
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.block_manager import (BlockManager, KVBlockManager,
                                      MMBlockManager, OutOfBlocks)


def test_allocate_free_roundtrip():
    bm = MMBlockManager(n_blocks=10, block_size=16)
    blocks = bm.allocate(1, 33)          # 3 blocks
    assert len(blocks) == 3
    assert bm.used_blocks == 3 and bm.free_blocks == 7
    assert bm.free(1) == 3
    assert bm.free_blocks == 10


def test_out_of_blocks():
    bm = KVBlockManager(n_blocks=2, block_size=16)
    bm.allocate(1, 16)
    with pytest.raises(OutOfBlocks):
        bm.allocate(2, 17)
    assert bm.can_allocate(16)


def test_append_grows_only_when_crossing():
    bm = KVBlockManager(n_blocks=8, block_size=16)
    bm.allocate(1, 20)                   # 2 blocks cover 32 tokens
    assert bm.append(1, 5, 20) == []     # 25 tokens still fit
    assert len(bm.append(1, 10, 25)) == 1  # 35 tokens -> 3rd block
    assert bm.used_blocks == 3


def test_free_unknown_request_is_noop():
    bm = MMBlockManager(4)
    assert bm.free(99) == 0


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 64),
                          st.booleans()), max_size=60))
def test_invariants_under_random_ops(ops):
    bm = BlockManager(n_blocks=16, block_size=4)
    live: dict[int, int] = {}
    for rid, tokens, do_free in ops:
        if do_free:
            got = bm.free(rid)
            assert got == live.pop(rid, 0)
        else:
            need = bm.blocks_for(tokens)
            if need <= bm.free_blocks:
                blocks = bm.allocate(rid, tokens)
                assert len(blocks) == need
                assert len(set(blocks)) == need          # no dup handouts
                live[rid] = live.get(rid, 0) + need
            else:
                with pytest.raises(OutOfBlocks):
                    bm.allocate(rid, tokens)
        # conservation
        assert bm.used_blocks + bm.free_blocks == bm.n_blocks
        assert bm.used_blocks == sum(live.values())
        owned = [b for r in live for b in bm.owner_blocks(r)]
        assert len(owned) == len(set(owned))             # single ownership


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 64))
def test_blocks_for_ceiling(tokens, bs):
    bm = BlockManager(n_blocks=1, block_size=bs)
    n = bm.blocks_for(tokens)
    assert (n - 1) * bs < tokens <= n * bs
