"""Block-manager unit + property tests (hypothesis).

Invariants: used + free == capacity; a block has at most one owner; free()
returns exactly what allocate()/append() handed out; OutOfBlocks precisely
when demand exceeds free blocks.
"""
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.block_manager import (BlockManager, KVBlockManager,
                                      MMBlockManager, OutOfBlocks)


def test_allocate_free_roundtrip():
    bm = MMBlockManager(n_blocks=10, block_size=16)
    blocks = bm.allocate(1, 33)          # 3 blocks
    assert len(blocks) == 3
    assert bm.used_blocks == 3 and bm.free_blocks == 7
    assert bm.free(1) == 3
    assert bm.free_blocks == 10


def test_out_of_blocks():
    bm = KVBlockManager(n_blocks=2, block_size=16)
    bm.allocate(1, 16)
    with pytest.raises(OutOfBlocks):
        bm.allocate(2, 17)
    assert bm.can_allocate(16)


def test_append_grows_only_when_crossing():
    bm = KVBlockManager(n_blocks=8, block_size=16)
    bm.allocate(1, 20)                   # 2 blocks cover 32 tokens
    assert bm.append(1, 5, 20) == []     # 25 tokens still fit
    assert len(bm.append(1, 10, 25)) == 1  # 35 tokens -> 3rd block
    assert bm.used_blocks == 3


def test_free_unknown_request_is_noop():
    bm = MMBlockManager(4)
    assert bm.free(99) == 0


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 64),
                          st.booleans()), max_size=60))
def test_invariants_under_random_ops(ops):
    bm = BlockManager(n_blocks=16, block_size=4)
    live: dict[int, int] = {}
    for rid, tokens, do_free in ops:
        if do_free:
            got = bm.free(rid)
            assert got == live.pop(rid, 0)
        else:
            need = bm.blocks_for(tokens)
            if need <= bm.free_blocks:
                blocks = bm.allocate(rid, tokens)
                assert len(blocks) == need
                assert len(set(blocks)) == need          # no dup handouts
                live[rid] = live.get(rid, 0) + need
            else:
                with pytest.raises(OutOfBlocks):
                    bm.allocate(rid, tokens)
        # conservation
        assert bm.used_blocks + bm.free_blocks == bm.n_blocks
        assert bm.used_blocks == sum(live.values())
        owned = [b for r in live for b in bm.owner_blocks(r)]
        assert len(owned) == len(set(owned))             # single ownership


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 64))
def test_blocks_for_ceiling(tokens, bs):
    bm = BlockManager(n_blocks=1, block_size=bs)
    n = bm.blocks_for(tokens)
    assert (n - 1) * bs < tokens <= n * bs


# ======================================================= prefix caching
import numpy as np


def _pc(n_blocks=8, bs=4, **kw):
    return KVBlockManager(n_blocks=n_blocks, block_size=bs,
                          prefix_cache=True, **kw)


def test_chain_keys_full_blocks_only_and_salt():
    bm = _pc()
    toks = np.arange(10, dtype=np.int32)         # 2 full blocks + tail
    keys = bm.chain_keys(toks)
    assert len(keys) == 2
    # chained: a change in block 0 changes block 1's key
    other = toks.copy()
    other[0] += 1
    assert bm.chain_keys(other)[1] != keys[1]
    # shared prefix, different tail -> same leading key
    assert bm.chain_keys(toks[:8])[0] == keys[0]
    # the mm salt re-roots the whole chain
    assert bm.chain_keys(toks, salt="img")[0] != keys[0]


def test_commit_match_and_shared_refcount():
    bm = _pc()
    toks = np.arange(8, dtype=np.int32)
    keys = bm.chain_keys(toks)
    t1 = bm.allocate(1, 9)                       # 3 blocks (8 tok + 1)
    assert bm.commit(1, keys) == 2
    res = bm.allocate_prefix(2, keys, 9)
    assert res is not None
    t2, matched = res
    assert matched == 2 and t2[:2] == t1[:2]     # shared blocks
    assert t2[2] != t1[2]                        # private tail
    assert bm.ref_count(t1[0]) == 2
    # freeing ONE owner never reclaims the shared block
    assert bm.free(1) == 3
    assert bm.ref_count(t2[0]) == 1
    assert bm.owner_blocks(2) == t2
    bm.free(2)
    # now unreferenced but still indexed: counts free, still matchable
    assert bm.free_blocks == bm.n_blocks
    assert bm.match_len(keys) == 2


def test_lru_eviction_only_unreferenced_and_on_pressure():
    bm = _pc(n_blocks=4, bs=4)
    a = np.arange(8, dtype=np.int32)
    ka = bm.chain_keys(a)
    bm.allocate(1, 8)                            # 2 blocks
    bm.commit(1, ka)
    b = np.arange(100, 108, dtype=np.int32)
    kb = bm.chain_keys(b)
    bm.allocate(2, 8)
    bm.commit(2, kb)
    bm.free(1)                                   # a's blocks -> LRU
    assert bm.prefix_evictions == 0
    # demand forces eviction of a's (unreferenced) blocks, never b's
    bm.allocate(3, 8)
    assert bm.prefix_evictions == 2
    assert bm.match_len(ka) == 0                 # evicted from the index
    assert bm.match_len(kb) == 2                 # still live-referenced
    with pytest.raises(OutOfBlocks):             # b is referenced: stuck
        bm.allocate(4, 4)


def test_cow_only_when_shared():
    bm = _pc()
    toks = np.arange(8, dtype=np.int32)
    keys = bm.chain_keys(toks)
    t1 = bm.allocate(1, 9)
    bm.commit(1, keys)
    t2, _ = bm.allocate_prefix(2, keys, 9)
    src = t2[1]
    res = bm.cow(2, 1)
    assert res is not None and res[0] == src
    assert bm.owner_blocks(2)[1] == res[1] != src
    assert bm.ref_count(src) == 1                # only req 1 now
    assert bm.cow_copies == 1
    # a private block needs no copy
    assert bm.cow(2, 1) is None
    assert bm.owner_blocks(1) == t1


def test_allocate_prefix_undoes_pins_on_failure():
    bm = _pc(n_blocks=4, bs=4)
    toks = np.arange(8, dtype=np.int32)
    keys = bm.chain_keys(toks)
    bm.allocate(1, 8)
    bm.commit(1, keys)
    # suffix needs 2 fresh blocks but only 2 exist and both are pinned
    bm.allocate(2, 8)
    assert bm.allocate_prefix(3, keys, 16) is None
    assert bm.ref_count(bm.owner_blocks(1)[0]) == 1   # pins rolled back
    assert bm.owner_blocks(3) == []


def test_match_caps_and_alignment():
    bm = _pc(n_blocks=16, bs=4)
    toks = np.arange(16, dtype=np.int32)
    keys = bm.chain_keys(toks)
    bm.allocate(1, 17)
    bm.commit(1, keys)
    _, matched = bm.allocate_prefix(2, keys, 17, max_match_blocks=3,
                                    align_blocks=2)
    assert matched == 2                          # capped 3, aligned down
    _, matched0 = bm.allocate_prefix(3, keys, 17, max_match_blocks=0)
    assert matched0 == 0


def test_inflight_claims_cleared_on_free_and_commit():
    bm = _pc()
    toks = np.arange(8, dtype=np.int32)
    keys = bm.chain_keys(toks)
    bm.allocate(1, 9)
    bm.register_inflight(1, keys)
    assert bm.inflight_holder(keys[0]) == 1
    # an aborted leader releases its claim
    bm.free(1)
    assert bm.inflight_holder(keys[0]) is None
    bm.allocate(2, 9)
    bm.register_inflight(2, keys)
    bm.commit(2, keys)
    assert bm.inflight_holder(keys[0]) is None
    assert bm.match_len(keys) == 2


def test_off_path_matches_base_semantics():
    base = KVBlockManager(n_blocks=8, block_size=4)
    assert base.prefix_cache is False
    b = base.allocate(1, 9)
    assert len(b) == 3 and base.free(1) == 3
    assert base.free_blocks == 8
