"""Encode–prefill overlap (streaming ψ_EP) + packed encode lanes.

Parity contract: overlap changes WHEN a prefill chunk runs, never WHAT
it computes — the watermark gate admits a chunk only after every
placeholder position it covers has its published shard tokens, and the
host-side scatter (``ShardStream.fill``) writes the exact float32 rows
the non-streaming ``embed_inputs`` merge would. Encode lanes move the
shard forward INTO the packed per-iteration program; the segment-wise
encoder attends each whole patch group identically whether batched as
``(1, k*tpi)`` or as lane rows ``(G, tpi)``, so greedy streams stay
bit-identical on every topology with overlap/lanes on or off.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Death, FaultPlan, Stall
from repro.models import build_model
from repro.serving import (ClusterConfig, ClusterEngine, EPDEngine,
                           EngineConfig, RequestState, ServeRequest)
from repro.serving.transfer import MMTokenCache

pytestmark = pytest.mark.cluster


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _ecfg(**kw):
    base = dict(n_encode_workers=2, max_new_tokens=8, decode_batch=2,
                kv_blocks=64, kv_block_size=16, max_seq_len=256)
    base.update(kw)
    return EngineConfig(**base)


def _requests(cfg, base_id, *, n_groups=2, n_mm=3, n_text=1, prompt_len=48):
    """Multimodal requests whose placeholder positions sit INSIDE the
    prompt (positions 4..4+M), so the watermark actually gates chunks."""
    rng = np.random.default_rng(42)
    tpi = cfg.modality.tokens_per_item
    M = n_groups * tpi
    reqs = []
    for i in range(n_mm + n_text):
        mm = i < n_mm
        reqs.append(ServeRequest(
            req_id=base_id + i,
            prompt=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1) if mm else None,
            mm_positions=(np.arange(4, 4 + M, dtype=np.int32)
                          if mm else None),
            max_new_tokens=8))
    return reqs


def _serve(engine, reqs):
    engine.start()
    try:
        for r in reqs:
            engine.submit(r)
        return {r.req_id - reqs[0].req_id: list(
            engine.result(r.req_id, timeout=300).tokens) for r in reqs}
    finally:
        engine.stop()


def _wait(pred, timeout=60.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.01)
    return pred()


@pytest.fixture(scope="module")
def ref_tokens(vlm_setup):
    """Greedy streams from the packed EPDEngine, overlap off."""
    cfg, params = vlm_setup
    return _serve(EPDEngine(cfg, params, _ecfg()), _requests(cfg, 0))


# ================================================== greedy bit-identity
@pytest.mark.parametrize("extra", [
    dict(encode_overlap=True, prefill_chunk=8),
    dict(runner="two_program", encode_overlap=True, prefill_chunk=8),
    dict(runner="two_program", encode_overlap=True),   # whole-prompt gate
    dict(encode_lanes=True),
    dict(encode_overlap=True, encode_lanes=True, prefill_chunk=8),
], ids=["packed-overlap", "two-program-overlap", "two-program-whole",
        "packed-lanes", "overlap+lanes"])
def test_overlap_greedy_bit_identity(vlm_setup, ref_tokens, extra):
    """Every overlap/lane mode emits the overlap-off token streams, bit
    for bit (acceptance: identical WHAT, earlier WHEN)."""
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, _ecfg(**extra))
    got = _serve(eng, _requests(cfg, 100))
    assert got == ref_tokens
    if extra.get("encode_lanes"):
        assert eng.stats["encode_lane_rows"] > 0
        # threaded E workers executed ZERO shards: all rode the lanes
        assert eng.stats["encode_shards"] == 6


def test_cluster_2e1p1d_overlap_parity(vlm_setup, ref_tokens):
    """True EPD disaggregation with streaming ψ_EP: shards encode on two
    E instances, the P instance's chunk frontier trails the shared
    stream's watermark, and the migrated decode stays bit-identical."""
    cfg, params = vlm_setup
    clu = ClusterEngine(cfg, params,
                        _ecfg(encode_overlap=True, prefill_chunk=8),
                        "2E1P1D")
    got = _serve(clu, _requests(cfg, 200))
    assert got == ref_tokens
    assert clu.stats["pd_migrations"] == 4       # one per request
    assert clu.stats["encode_shards"] == 6       # 3 mm requests x IRP 2


# ============================================ watermark-gated admission
def test_watermark_gates_chunk_admission(vlm_setup):
    """Deterministic single-thread drive of the packed scheduler: a
    still-encoding request is admitted immediately, its chunk frontier
    stops exactly at the encoded watermark, and publishing the missing
    shard releases it. Chunk = 16 (block-aligned), prompt = 40,
    placeholders at 4..35 split into two 16-token shards."""
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, _ecfg(encode_overlap=True,
                                       prefill_chunk=8))
    [req] = _requests(cfg, 300, n_mm=1, n_text=0, prompt_len=40)
    sched = eng.scheduler
    stream = eng.psi_ep.open_stream(req)
    req.advance(RequestState.ENCODING)
    req.advance(RequestState.PREFILLING)
    eng.psi_ep.send(req, stream)

    for _ in range(3):
        sched.step()
    task = sched.task
    assert task is not None, "streaming request was not admitted"
    assert task.n_done == 0          # no shard yet: frontier at 0
    assert eng.stats["overlap_chunks_early"] == 0

    shards = eng.encode_stage.plan_shards(req)
    assert len(shards) == 2
    tok0 = eng.encode_stage.encode_shard(req, shards[0])
    assert eng.psi_ep.add_shard(req, 0, 2, shards[0], tok0) is None
    for _ in range(4):
        sched.step()
    # shard 0 covers placeholders 4..19: chunk [0,16) ran early, chunk
    # [16,32) is blocked on position 20 — the encoded watermark
    assert sched.task is task and task.n_done == 16
    assert task.mm_tokens is None                # still streaming
    assert eng.stats["overlap_chunks_early"] == 1
    assert eng.stats["overlap_watermark_hwm"] == 20

    tok1 = eng.encode_stage.encode_shard(req, shards[1])
    merged = eng.psi_ep.add_shard(req, 1, 2, shards[1], tok1)
    assert merged is not None and merged.shape[0] == req.mm_embeds.shape[0]
    for _ in range(200):
        sched.step()
        if req.finished:
            break
    assert req.state is RequestState.DONE
    assert len(req.tokens) == 8


# ======================================================= fault tolerance
def test_mid_stream_death_replays_only_unencoded_shards(vlm_setup,
                                                        ref_tokens):
    """Kill an E instance while requests are mid-stream (its queued
    shard jobs stalled, siblings' shards already published). Failover
    reroutes ONLY the unencoded shards — every shard forward runs
    exactly once cluster-wide — and the streams complete wherever the
    survivors encode: tokens stay bit-identical to an undisturbed run."""
    cfg, params = vlm_setup
    # stall instance 0 (an E) from birth so its routed jobs sit queued,
    # then kill it; monitor_interval is huge — the test sweeps itself
    plan = FaultPlan(stalls=[Stall(iid=0, start=0.0, duration=3600.0)],
                     deaths=[Death(iid=0, at=1.0)])
    clu = ClusterEngine(cfg, params,
                        _ecfg(encode_overlap=True, prefill_chunk=8),
                        ClusterConfig(spec="2E1P1D",
                                      monitor_interval=60.0),
                        faults=plan)
    victim = clu.instances[0]
    assert victim.role == "E"
    clu.start()
    try:
        reqs = _requests(cfg, 400)
        for r in reqs:
            clu.submit(r)
        assert _wait(lambda: not victim.alive), "executor ignored death"
        clu.supervise_once()                    # failover sweep
        outs = {r.req_id - 400: list(
            clu.result(r.req_id, timeout=300).tokens) for r in reqs}
    finally:
        clu.stop()
    assert outs == ref_tokens
    assert clu.stats["instance_deaths"] == 1
    assert clu.stats["jobs_rerouted"] >= 1      # victim held queued shards
    # replay is precise: 3 mm requests x 2 shards, each encoded ONCE
    assert clu.stats["encode_shards"] == 6


# ================================================== prefix-cache compose
def test_overlap_composes_with_prefix_cache(vlm_setup):
    """The prefix salt is the hash of the FULL mm payload (raw embeds +
    positions), not of whatever had streamed in — so a repeat of a
    streamed request hits the prefix cache and stays bit-identical."""
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, _ecfg(encode_overlap=True,
                                       prefill_chunk=8,
                                       prefix_cache=True))
    [a] = _requests(cfg, 500, n_mm=1, n_text=0)
    [b] = _requests(cfg, 501, n_mm=1, n_text=0)   # same rng -> same bytes
    assert np.array_equal(a.prompt, b.prompt)
    eng.start()
    try:
        eng.submit(a)
        ta = list(eng.result(500, timeout=300).tokens)
        eng.submit(b)
        tb = list(eng.result(501, timeout=300).tokens)
    finally:
        eng.stop()
    assert ta == tb
    assert eng.stats["prefix_cache_hits"] >= 1
    assert eng.stats["prefix_tokens_reused"] > 0


# ================================================== mm-cache full-merge
def test_mm_cache_refuses_partial_merge():
    """Streaming makes a truncated entry a real hazard: ``put`` refuses
    any token set that is not the request's full merge."""
    cache = MMTokenCache(capacity=4)
    tokens = np.ones((6, 8), np.float32)
    with pytest.raises(ValueError, match="partial/streaming"):
        cache.put("k", tokens, n_expected=10)
    with pytest.raises(ValueError):
        cache.put("k", None, n_expected=10)
    cache.put("k", tokens, n_expected=6)         # full merge commits
    assert cache.get("k") is tokens
    assert len(cache) == 1


# ==================================================== encode-lane shapes
def test_encode_lanes_ragged_parity_and_compile_stability(vlm_setup):
    """Lane rows cover every shard shape: whole groups, a trailing
    ragged group riding with a whole one, and the one legacy shape (a
    single ragged group alone, which attends unpadded and routes through
    ``encode_fn``). A second identical wave adds ZERO compiled shapes to
    the packed program OR the encoder — lane load can never drive a
    mid-run recompile."""
    cfg, params = vlm_setup
    tpi = cfg.modality.tokens_per_item
    # M = 2*tpi + 5 -> 3 groups; 3 E workers -> shards [tpi],[tpi],[5]:
    # the last is the single-ragged-alone legacy shape
    eng = EPDEngine(cfg, params, _ecfg(n_encode_workers=3,
                                       encode_lanes=True))
    ref = EPDEngine(cfg, params, _ecfg(n_encode_workers=3))

    def wave(engine, base):
        rng = np.random.default_rng(5)
        M = 2 * tpi + 5
        reqs = [ServeRequest(
            req_id=base + i,
            prompt=rng.integers(0, cfg.vocab, 48).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1),
            mm_positions=np.arange(4, 4 + M, dtype=np.int32),
            max_new_tokens=6) for i in range(2)]
        return _serve_started(engine, reqs)

    def _serve_started(engine, reqs):
        for r in reqs:
            engine.submit(r)
        return [list(engine.result(r.req_id, timeout=300).tokens)
                for r in reqs]

    ref.start()
    try:
        expect = wave(ref, 0)
    finally:
        ref.stop()
    eng.start()
    try:
        assert wave(eng, 100) == expect
        assert eng.stats["encode_lane_rows"] > 0
        assert eng.stats["encode_shards"] == 6   # 2 reqs x 3 shards
        warm_packed = eng.stats["packed_compiles"]
        warm_enc = int(eng.kit.encode_fn._cache_size())
        assert wave(eng, 200) == expect
        assert eng.stats["packed_compiles"] == warm_packed
        assert int(eng.kit.encode_fn._cache_size()) == warm_enc
    finally:
        eng.stop()
