"""Discrete-event simulator behaviour: the paper's qualitative claims must
hold as system invariants, plus hypothesis properties on timestamps."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import A100_80G, SLO, simulate, summarize
from repro.core.cluster import ClusterSpec
from repro.data.workload import WorkloadSpec, poisson_requests

CFG = get_config("minicpm-v-2.6")
SLO_2IMG = SLO(ttft=1.40, tpot=0.04)


def _work(rate=0.5, n=60, items=2, out_len=10, seed=0):
    return poisson_requests(CFG, WorkloadSpec(
        rate=rate, n_requests=n, n_items=items, output_len=out_len,
        slo=SLO_2IMG, seed=seed))


def test_all_requests_finish():
    out = simulate(ClusterSpec("5E2P1D"), CFG, A100_80G, _work())
    assert all(r.done() for r in out)


def test_timestamps_monotone():
    out = simulate(ClusterSpec("5E2P1D"), CFG, A100_80G, _work())
    for r in out:
        assert r.arrival <= r.enc_start <= r.enc_end
        assert r.enc_end <= r.ep_transfer_end <= r.prefill_end
        assert r.prefill_end <= r.pd_transfer_end <= r.finish


def test_epd_beats_aggregated_ttft():
    """Fig 5 / Table 4: EPD < DistServe = vLLM on TTFT for encode-heavy
    multimodal workloads."""
    reqs = _work(rate=0.5)
    epd = summarize(simulate(ClusterSpec("5E2P1D", irp=True), CFG,
                             A100_80G, reqs), SLO_2IMG)
    dist = summarize(simulate(ClusterSpec("7EP1D", irp=False), CFG,
                              A100_80G, reqs), SLO_2IMG)
    vllm = summarize(simulate(ClusterSpec("8EPD", irp=False), CFG,
                              A100_80G, reqs), SLO_2IMG)
    assert epd.ttft_mean < dist.ttft_mean
    assert epd.ttft_mean < vllm.ttft_mean
    assert epd.slo_attainment >= dist.slo_attainment
    assert epd.slo_attainment >= vllm.slo_attainment


def test_irp_reduces_ttft():
    """Table 4: ablating IRP hurts TTFT, worse with more images/request."""
    for items in (2, 4, 8):
        reqs = _work(rate=0.25, items=items)
        with_irp = summarize(simulate(
            ClusterSpec("5E2P1D", irp=True), CFG, A100_80G, reqs))
        without = summarize(simulate(
            ClusterSpec("5E2P1D", irp=False), CFG, A100_80G, reqs))
        assert with_irp.ttft_mean < without.ttft_mean, f"items={items}"


def test_interference_under_load():
    """Fig 1: aggregated executors interfere — vLLM TPOT degrades as rate
    grows while disaggregated decode stays flat."""
    vllm = ClusterSpec("8EPD", irp=False, assign_policy="round_robin")
    lo = summarize(simulate(vllm, CFG, A100_80G, _work(rate=0.05, out_len=50)))
    hi = summarize(simulate(vllm, CFG, A100_80G, _work(rate=8.0, out_len=50)))
    epd_hi = summarize(simulate(ClusterSpec("5E2P1D"), CFG, A100_80G,
                                _work(rate=8.0, out_len=50)))
    assert hi.tpot_mean > lo.tpot_mean * 1.5       # decode starved by E/P
    assert epd_hi.tpot_mean < lo.tpot_mean * 1.1   # disaggregated D is flat


def test_role_switching_improves_changing_workload():
    """Table 6: a workload that shifts from short to long outputs benefits
    from dynamic role switching (5E1P2D reconfigures toward decode)."""
    short = poisson_requests(CFG, WorkloadSpec(
        rate=3.0, n_requests=10, n_items=1, output_len=50, slo=SLO_2IMG))
    long_ = poisson_requests(CFG, WorkloadSpec(
        rate=3.0, n_requests=90, n_items=1, output_len=500, slo=SLO_2IMG,
        seed=1))
    for i, r in enumerate(long_):
        r.req_id = 100 + i
        r.arrival += short[-1].arrival
    reqs = short + long_
    # paper E.1: latency experiments run with small per-stage batches
    static = summarize(simulate(
        ClusterSpec("5E1P2D", role_switch=False, decode_batch=4),
        CFG, A100_80G, reqs))
    dynamic = summarize(simulate(
        ClusterSpec("5E1P2D", role_switch=True, decode_batch=4),
        CFG, A100_80G, reqs))
    assert dynamic.latency_mean < static.latency_mean / 1.5
    assert dynamic.tpot_mean < static.tpot_mean / 1.5


def test_text_only_requests_skip_encode():
    cfg = get_config("internlm2-20b")  # no modality
    from repro.core.request import Request
    reqs = [Request(req_id=i, arrival=i * 0.5, prompt_len=128, n_items=0,
                    patches_per_item=0, tokens_per_patch=0, output_len=5,
                    slo=SLO(5.0, 0.5)) for i in range(10)]
    out = simulate(ClusterSpec("7P1D", irp=False), cfg, A100_80G, reqs)
    assert all(r.done() for r in out)
    assert all(r.enc_end == r.enc_start for r in out)


def test_decode_rotation_no_tail_starvation():
    """Regression: the decode batch was always ``decode_slots[:n]``, so
    with residency > decode_batch the tail slots never received a step
    until the head requests finished — long-output heads starved the
    tail indefinitely. The rotating window must give EVERY resident
    progress within a bounded number of steps."""
    import heapq

    from repro.core.instance import DecodeSlot, Instance
    from repro.core.request import Request
    from repro.core.simulator import Simulator

    cfg = get_config("internlm2-20b")              # text-only: D is enough
    inst = Instance("D", 1, cfg, A100_80G, decode_batch=2)
    sim = Simulator(cfg, A100_80G, [inst])
    out_len = 40
    for i in range(6):                             # residency 3x the batch
        sim.requests[i] = Request(
            req_id=i, arrival=0.0, prompt_len=16, n_items=0,
            patches_per_item=0, tokens_per_patch=0, output_len=out_len,
            slo=SLO(5.0, 0.5))
        inst.decode_slots.append(DecodeSlot(i, 17, out_len))
    sim._maybe_decode(inst)
    for _ in range(30):                            # 30 steps x batch 2
        ev = heapq.heappop(sim._events)
        sim.now = ev.time
        getattr(sim, "_on_" + ev.kind)(ev)
    assert len(inst.decode_slots) == 6             # nobody finished yet
    # every slot advanced; without rotation slots [2:] sit at out_len
    for s in inst.decode_slots:
        assert s.remaining < out_len, f"slot {s.req_id} starved"


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(0.05, 2.0), items=st.integers(1, 6),
       out_len=st.integers(1, 40), seed=st.integers(0, 5))
def test_property_all_finish_any_workload(rate, items, out_len, seed):
    reqs = _work(rate=rate, n=20, items=items, out_len=out_len, seed=seed)
    out = simulate(ClusterSpec("5E2P1D"), CFG, A100_80G, reqs)
    assert all(r.done() for r in out)
    for r in out:
        assert r.ttft > 0 and r.e2e_latency >= r.ttft
