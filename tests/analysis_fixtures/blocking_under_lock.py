"""Golden fixture: blocking calls while holding a lock -> RL002."""
import threading
import time

state_lock = threading.Lock()


def slow_update(worker, jobs):
    with state_lock:
        time.sleep(0.1)
        worker.join()
        jobs.get()
