"""Golden fixture: idiomatic concurrency + jit code -> ZERO findings.

Every pattern here is the blessed counterpart of one of the bad
fixtures: predicate-looped wait, consistent single-lock discipline,
pure jitted math, and a bucket-laddered call site.
"""
import threading

import jax
import jax.numpy as jnp

lock = threading.Lock()
cv = threading.Condition(lock)
_done = False


def wait_done():
    with cv:
        while not _done:
            cv.wait()


@jax.jit
def scaled_sum(x):
    return jnp.sum(x) * 2.0


def run(xs, bucket_sizes):
    n = len(xs)
    width = next(b for b in bucket_sizes if n <= b)
    x = jnp.zeros((width,), jnp.float32)
    return scaled_sum(x)
