"""Golden fixture: jit call site whose input extent is data-dependent
and never flows through a bucket ladder -> RJ103."""
import jax
import jax.numpy as jnp


@jax.jit
def kernel(x):
    return x * 2.0


def run(tokens):
    n = len(tokens)
    x = jnp.zeros((n,), jnp.float32)
    return kernel(x)
