"""Golden fixture: condvar wait outside a predicate loop -> RL003."""
import threading

cv = threading.Condition()


def consume():
    with cv:
        cv.wait()
