"""Golden fixture: jit-wrapped lambda closing over a loop variable ->
RJ102 (every compiled fn sees the last value)."""
import jax


def build():
    compiled = []
    for scale in (1.0, 2.0):
        compiled.append(jax.jit(lambda x: x * scale))
    return compiled
