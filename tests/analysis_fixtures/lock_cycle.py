"""Golden fixture: inconsistent acquisition order -> RL001 (+RL004)."""
import threading

table_lock = threading.Lock()
stats_lock = threading.Lock()


def forward():
    with table_lock:
        with stats_lock:
            pass


def backward():
    with stats_lock:
        with table_lock:
            pass
