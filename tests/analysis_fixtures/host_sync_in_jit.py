"""Golden fixture: host synchronisation inside a jitted fn -> RJ101."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_norm(x):
    s = jnp.sum(x)
    host = np.asarray(s)
    return host, s.item()
