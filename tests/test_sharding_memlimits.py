"""Sharding rules + memory-limit calculators + whisper EPD prefill path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import A100_80G
from repro.core import memlimits as ml


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class _FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def _pspec(path_keys, shape, mesh=None):
    from repro.launch.sharding import param_pspec

    class K:
        def __init__(self, k):
            self.key = k
    return param_pspec([K(k) for k in path_keys], shape, mesh or _FakeMesh())


def test_generic_two_d_rule():
    assert _pspec(["layers", "mlp", "wi_gate"], (32, 4096, 14336)) \
        == P(None, "data", "model")


def test_indivisible_dims_replicate():
    # vocab 49155 not divisible by 16 on either axis
    assert _pspec(["embed"], (49155, 1536)) == P(None, "model")
    assert _pspec(["head"], (1536, 49155)) == P("data", None)


def test_moe_expert_parallel_only():
    spec = _pspec(["layers", "moe", "wi_gate"], (48, 128, 2048, 768))
    assert spec == P(None, "model", None, None)


def test_moe_router_replicated():
    assert _pspec(["layers", "moe", "router"], (48, 2048, 128)) == P(None, None, None)


def test_pod_axis_joins_fsdp():
    spec = _pspec(["layers", "attn", "wq"], (88, 12288, 12288), _FakePodMesh())
    assert spec == P(None, ("pod", "data"), "model")


def test_cache_pspec_kv_seq_sharded():
    from repro.launch.sharding import cache_pspec

    class K:
        def __init__(self, k):
            self.key = k
    spec = cache_pspec([K("cache"), K("k")], (32, 128, 32768, 8, 128),
                       _FakeMesh())
    assert spec == P(None, "data", "model", None, None)
    # batch=1 long context: seq sharded over everything available
    spec1 = cache_pspec([K("cache"), K("k")], (32, 1, 524288, 8, 128),
                        _FakeMesh())
    assert spec1[2] is not None


# ------------------------------------------------------------- memlimits
def test_effective_patches_tile_budget():
    ivl = get_config("internvl2-8b")
    assert ml.effective_patches(ivl, (4032, 3024), 1) == 12   # budget 12
    assert ml.effective_patches(ivl, (4032, 3024), 6) == 2
    assert ml.effective_patches(ivl, (4032, 3024), 40) == 1
    mini = get_config("minicpm-v-2.6")
    assert ml.effective_patches(mini, (4032, 3024), 40) == 10  # no budget


def test_max_images_monotone_in_memory():
    cfg = get_config("minicpm-v-2.6")
    e = ml.max_images_per_request(cfg, A100_80G, "E", (4032, 3024))
    ep = ml.max_images_per_request(cfg, A100_80G, "EP", (4032, 3024))
    assert isinstance(e, int) and isinstance(ep, int)
    assert e > ep


def test_kv_percent_oocl_on_context_blowout():
    cfg = get_config("minicpm-v-2.6")   # ctx 32768; 80 img x 10 x 64 > ctx
    assert ml.max_kv_percent(cfg, A100_80G, "P", images_per_req=80) == ml.OOCL


# ------------------------------------------------- whisper EPD prefill path
def test_whisper_prefill_accepts_precomputed_enc_out(rng_key):
    cfg = get_config("whisper-large-v3").reduced()
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(rng_key)
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)),
                         jnp.bfloat16)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)), jnp.int32)
    # aggregated path
    l1, _ = model.prefill(params, batch={"tokens": tokens,
                                         "enc_frames": frames})
    # EPD path: E ran elsewhere, ψ_EP shipped enc_out
    enc_out = model.encode(params, frames)
    l2, _ = model.prefill(params, batch={"tokens": tokens,
                                         "enc_frames": frames,
                                         "enc_out": enc_out})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=1e-2,
                               atol=1e-2)
