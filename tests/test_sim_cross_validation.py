"""Sim-vs-real cross-validation: the same request trace through the
real ClusterEngine and the discrete-event ``core.simulator.Simulator``
must agree on STRUCTURAL metrics — completion counts, per-stage job
counts (IRP encode shards, prefills, decode steps), preemption and
role-switch counts. Wall-clock timings are never compared: the sim uses
the analytical cost model and this container's timings are noisy.

This is the contract the resource allocator relies on (§3.2.3: the
allocator optimizes over the simulator, the engine must execute the
same cluster language).
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100_80G
from repro.core.cluster import ClusterSpec, build_cluster
from repro.core.request import Request
from repro.core.simulator import Simulator
from repro.models import build_model
from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           RequestState, ServeRequest)

pytestmark = pytest.mark.cluster

N_REQ = 6
OUT_LEN = 6
PROMPT = 16
IRP = 2


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _trace_pair(cfg):
    """One logical trace, in both dialects: even-indexed requests carry a
    2-patch-group modality payload, odd ones are text-only. The sim side
    is DERIVED from the serve side via ``api.sim_request_of`` — the same
    conversion the cluster's LoadEstimator feed uses."""
    from repro.serving.api import sim_request_of
    rng = np.random.default_rng(11)
    tpi = cfg.modality.tokens_per_item
    serve, sim = [], []
    for i in range(N_REQ):
        mm = (i % 2 == 0)
        M = 2 * tpi
        serve.append(ServeRequest(
            req_id=i, prompt=rng.integers(0, cfg.vocab, PROMPT).astype(
                np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1) if mm else None,
            mm_positions=(np.arange(1, M + 1, dtype=np.int32)
                          if mm else None),
            max_new_tokens=OUT_LEN))
        sim.append(sim_request_of(cfg, serve[-1], arrival=0.05 * i))
    return serve, sim


def test_structural_agreement_2e1p1d(vlm_setup):
    cfg, params = vlm_setup
    serve_reqs, sim_reqs = _trace_pair(cfg)

    # ---- real engine (mm cache off so every mm request really encodes,
    # matching the simulator which has no cross-request token cache)
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=IRP, max_new_tokens=OUT_LEN,
                     decode_batch=4, mm_cache_entries=0),
        "2E1P1D")
    clu.start()
    try:
        for r in serve_reqs:
            clu.submit(r)
            time.sleep(0.01)
        outs = [clu.result(r.req_id, timeout=300) for r in serve_reqs]
    finally:
        clu.stop()

    # ---- simulator, same topology and IRP degree
    spec = ClusterSpec("2E1P1D", irp=True, irp_degree=IRP)
    sim = Simulator(cfg, A100_80G, build_cluster(spec, cfg, A100_80G),
                    irp=True, irp_degree=IRP)
    sim_out = sim.run(sim_reqs)

    # completion counts
    assert sum(o.state is RequestState.DONE for o in outs) == N_REQ
    assert sum(r.done() for r in sim_out) == N_REQ
    # per-stage job counts: encode shards (IRP), prefills, decode steps
    assert clu.stats["encode_shards"] == \
        sum(len(r.shard_done) for r in sim_out)
    assert clu.stats["prefill_completions"] == \
        sum(1 for r in sim_out if r.prefill_end >= 0)
    # engine decode_tokens counts slot-steps = (output_len - 1) per
    # request (the first token comes from prefill), exactly the
    # simulator's per-request decode-step residency
    assert clu.stats["decode_tokens"] == \
        sum(r.output_len - 1 for r in sim_out)
    # emitted lengths agree request-by-request
    assert {o.req_id: len(o.tokens) for o in outs} == \
        {r.req_id: r.output_len for r in sim_out}
    # neither side preempted or switched
    assert clu.stats["preemptions"] == 0
    assert clu.stats["role_switches"] == 0 and not sim.switch_log


def test_role_switch_direction_agreement(vlm_setup):
    """Under the same encode-heavy -> decode-heavy shift, both the engine
    monitor (LoadEstimator-driven) and the simulator monitor (queue-
    pressure-driven) re-role an E instance to D — structural agreement
    on switch count (>= 1) and direction, not on timing."""
    cfg, params = vlm_setup
    tpi = cfg.modality.tokens_per_item
    rng = np.random.default_rng(12)

    # ---- simulator side
    short = [Request(req_id=100 + i, arrival=0.2 * i, prompt_len=PROMPT,
                     n_items=2, patches_per_item=1, tokens_per_patch=tpi,
                     output_len=5) for i in range(6)]
    long_ = [Request(req_id=200 + i, arrival=short[-1].arrival + 0.2 * i,
                     prompt_len=PROMPT, n_items=0, patches_per_item=1,
                     tokens_per_patch=tpi, output_len=400)
             for i in range(30)]
    spec = ClusterSpec("3E1P1D", role_switch=True, decode_batch=4)
    sim = Simulator(cfg, A100_80G, build_cluster(spec, cfg, A100_80G),
                    role_switch=True, monitor_interval=0.5)
    sim_out = sim.run(short + long_)
    assert sum(r.done() for r in sim_out) == len(short) + len(long_)
    assert len(sim.switch_log) >= 1
    sim_first = sim.switch_log[0]

    # ---- real engine, same shape of shift (shorter outputs: real math)
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=2, max_new_tokens=24, decode_batch=2),
        ClusterConfig(spec="3E1P1D", role_switch=False))
    clu.start()
    try:
        M = 2 * tpi
        for i in range(4):
            clu.submit(ServeRequest(
                req_id=i,
                prompt=rng.integers(0, cfg.vocab, PROMPT).astype(np.int32),
                mm_embeds=rng.standard_normal(
                    (M, cfg.modality.enc_d_model)).astype(np.float32) * 0.1,
                mm_positions=np.arange(1, M + 1, dtype=np.int32),
                max_new_tokens=2))
        for i in range(4):
            clu.result(i, timeout=300)
        ids = list(range(10, 26))
        for i in ids:
            clu.submit(ServeRequest(
                req_id=i,
                prompt=rng.integers(0, cfg.vocab, PROMPT).astype(np.int32),
                max_new_tokens=24))
            time.sleep(0.005)
        eng_switch = None
        for _ in range(200):
            eng_switch = clu.monitor_once()
            if eng_switch:
                break
            time.sleep(0.02)
        for i in ids:
            clu.result(i, timeout=300)
    finally:
        clu.stop()
    assert eng_switch is not None
    # direction agreement: both monitors re-role E -> D first
    assert (sim_first[2], sim_first[3]) == ("E", "D")
    assert (eng_switch[1], eng_switch[2]) == ("E", "D")
