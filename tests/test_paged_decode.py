"""Paged-batched decode stage: parity vs the dense per-request loop,
OutOfBlocks-under-pressure preemption, and deterministic shutdown.

The paged engine packs all active decode requests into ONE jitted
``paged_decode_step`` per iteration over a shared ``KVBlockManager`` pool;
greedy decode must emit exactly the tokens the seed dense per-request loop
emits (same params, same math, different cache layout).
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core.block_manager import OutOfBlocks
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig, ServeRequest


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n_new=4):
    rng = np.random.default_rng(7)
    M = 2 * cfg.modality.tokens_per_item
    reqs = [ServeRequest(
        req_id=1,
        prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
        mm_embeds=rng.standard_normal(
            (M, cfg.modality.enc_d_model)).astype(np.float32) * 0.1,
        mm_positions=np.arange(1, M + 1, dtype=np.int32),
        max_new_tokens=n_new)]
    for i in (2, 3):
        reqs.append(ServeRequest(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
            max_new_tokens=n_new))
    return reqs


def _serve(cfg, params, mode, reqs):
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, max_new_tokens=4, decode_batch=4, mode=mode,
        kv_blocks=64, max_seq_len=128))
    eng.start()
    try:
        for r in reqs:
            eng.submit(r)
        out = {r.req_id: eng.result(r.req_id, timeout=300) for r in reqs}
    finally:
        eng.stop()
    return out, eng


def test_paged_matches_dense_tokens(vlm_setup):
    """Batched paged decode must be token-identical to the seed loop for
    both multimodal (E -> psi_EP -> P) and text-only requests."""
    cfg, params = vlm_setup
    paged, eng = _serve(cfg, params, "paged", _requests(cfg))
    dense, _ = _serve(cfg, params, "dense", _requests(cfg))
    for rid in paged:
        assert paged[rid].tokens == dense[rid].tokens, f"req {rid}"
        assert len(paged[rid].tokens) == 4
    # every block returned to the pool after completion
    assert eng.kv_mgr.used_blocks == 0
    # the batched loop stepped, and one call covered multiple requests
    assert eng.stats["decode_steps"] > 0
    assert eng.stats["decode_tokens"] >= eng.stats["decode_steps"]


def test_out_of_blocks_preempts_and_recovers():
    """Decode-time block-pool pressure: the victim request is preempted
    (blocks freed, requeued through P) instead of crashing, and both
    requests still complete with full outputs."""
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # prompt 15 -> 1 block (bs=16) at prefill; first append crosses into a
    # second block. 3-block pool cannot hold two grown sequences at once.
    reqs = [ServeRequest(req_id=i,
                         prompt=rng.integers(0, cfg.vocab, 15).astype(np.int32),
                         max_new_tokens=8) for i in (1, 2)]
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=1, max_new_tokens=8, decode_batch=2, mode="paged",
        kv_blocks=3, kv_block_size=16, max_seq_len=64))
    eng.start()
    try:
        for r in reqs:
            eng.submit(r)
        outs = [eng.result(r.req_id, timeout=300) for r in reqs]
    finally:
        eng.stop()
    for o in outs:
        assert len(o.tokens) == 8
    assert eng.stats["preemptions"] >= 1
    assert eng.kv_mgr.used_blocks == 0


def test_stop_joins_worker_threads(vlm_setup):
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=32, max_seq_len=64))
    eng.start()
    req = ServeRequest(req_id=9, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=2)
    eng.submit(req)
    assert len(eng.result(9, timeout=300).tokens) == 2
    eng.stop()
    assert eng._threads == []            # every worker joined


def test_paged_prefill_writes_pool_blocks():
    """dense.paged_prefill = prefill_core + pool scatter: logits must match
    dense.prefill and the owned blocks must hold exactly the prompt's KV."""
    import jax.numpy as jnp
    from repro.models import dense
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(2))
    S, bs = 20, 16
    toks = jnp.arange(S, dtype=jnp.int32)[None] % cfg.vocab
    ref_logits, cache = dense.prefill(params, cfg, {"tokens": toks})
    k_pool, v_pool = dense.init_kv_pool(cfg, 8, bs)
    ids = jnp.asarray([5, 2], jnp.int32)            # non-contiguous blocks
    logits, k_pool, v_pool = dense.paged_prefill(
        params, cfg, {"tokens": toks}, k_pool=k_pool, v_pool=v_pool,
        block_ids=ids)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref_logits))
    L, _, _, K, hd = k_pool.shape
    gathered = np.asarray(k_pool[:, ids]).reshape(L, 2 * bs, K, hd)[:, :S]
    np.testing.assert_array_equal(
        gathered, np.asarray(cache["k"][:, 0].astype(k_pool.dtype)))


def test_paged_prefill_rejects_sliding_window():
    from dataclasses import replace
    from repro.models import dense
    cfg = replace(get_config("minitron-4b").reduced(), sliding_window=32)
    with pytest.raises(NotImplementedError):
        dense.paged_prefill(None, cfg, {}, k_pool=None, v_pool=None,
                            block_ids=None)


def test_oversized_request_rejected_at_submit(vlm_setup):
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=32, max_seq_len=32))
    with pytest.raises(ValueError):
        eng.submit(ServeRequest(req_id=1,
                                prompt=np.zeros(30, np.int32),
                                max_new_tokens=8))
