"""Token-packed ModelRunner + attention-backend registry.

Parity contract: the packed runner (the default execution path) must be
GREEDY BIT-IDENTICAL to the historical two-program path it replaced —
a decode row is exactly the batched step's row, and a chunk row's
scatter-then-paged-attention read sees the same valid KV entries in the
same order as ``prefix_chunk_attention`` (masked-softmax padding is
exact). Nucleus sampling is included against the SAME-kernel oracle
(``two_program`` under ``ref``): logits are bit-identical there, so the
seeded sampler draws the same tokens. Cross-BACKEND (ref vs pallas)
output is only ULP-close (the documented nucleus/tie caveat), so the
backend-parametrized tests assert self-consistency, not cross-equality.
"""
import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.kernels.registry import (ENV_VAR, available_backends,
                                    get_backend, resolve_backend)
from repro.models import build_model, dense
from repro.serving import (ClusterEngine, EngineConfig, EPDEngine,
                           SamplingParams, ServeRequest)


@pytest.fixture(scope="module")
def text_setup():
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lengths, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lengths]


def _serve(cfg, params, prompts, max_new=6, engine_cls=EPDEngine,
           topo=None, **ecfg_kw):
    base = dict(decode_batch=2, kv_blocks=64, max_seq_len=256,
                prefill_chunk=32)
    base.update(ecfg_kw)
    ecfg = EngineConfig(**base)
    eng = (engine_cls(cfg, params, ecfg) if topo is None
           else engine_cls(cfg, params, ecfg, topo))
    eng.start()
    try:
        for i, p in enumerate(prompts):
            eng.submit(ServeRequest(req_id=i + 1, prompt=p.copy(),
                                    max_new_tokens=max_new))
        return [eng.result(i + 1, timeout=300).tokens
                for i in range(len(prompts))], eng
    finally:
        eng.stop()


# ================================================== registry + env plumbing
def test_registry_names_and_validation():
    assert set(available_backends()) >= {"ref", "pallas"}
    with pytest.raises(ValueError, match="unknown attention backend"):
        get_backend("bogus")
    with pytest.raises(ValueError, match="available"):
        resolve_backend("nope")


def test_env_var_selects_and_validates(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "pallas")
    assert resolve_backend(None).name == "pallas"
    # explicit config name wins over the env
    assert resolve_backend("ref").name == "ref"
    monkeypatch.setenv(ENV_VAR, "tyop")
    with pytest.raises(ValueError, match="tyop"):
        resolve_backend(None)


def test_engine_rejects_bad_backend_and_runner(text_setup, monkeypatch):
    cfg, params = text_setup
    with pytest.raises(ValueError, match="unknown attention backend"):
        EPDEngine(cfg, params, EngineConfig(attn_backend="nope"))
    with pytest.raises(ValueError, match="runner"):
        EPDEngine(cfg, params, EngineConfig(runner="fused"))
    # a zero-length prompt has no last-token row to sample from (and a
    # zero-length final chunk would alias another row's sampling state)
    eng = EPDEngine(cfg, params, EngineConfig())
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(ServeRequest(req_id=1, prompt=np.zeros(0, np.int32),
                                max_new_tokens=4))
    # a typo'd env var fails engine construction instead of silently
    # serving on the default backend
    monkeypatch.setenv(ENV_VAR, "palas")
    with pytest.raises(ValueError, match="palas"):
        EPDEngine(cfg, params, EngineConfig())


# ===================================================== packed math (no engine)
def test_packed_core_matches_decode_and_prefill_cores(text_setup):
    """One packed call reproduces BOTH sub-programs bit-for-bit: a whole
    prompt as chunk rows == ``prefill_core`` (logits and pool KV), and a
    decode row at a wider packed batch == ``paged_decode_step``."""
    cfg, params = text_setup
    model = build_model(cfg)
    rng = np.random.default_rng(7)
    bs, n_blocks, max_blocks = 16, 32, 8
    trash = n_blocks
    k_pool, v_pool = model.init_kv_pool(n_blocks, bs)
    S = 12
    prompt = rng.integers(0, cfg.vocab, S).astype(np.int32)
    ref_logits, rks, _ = jax.jit(lambda p, b: dense.prefill_core(p, cfg, b))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    x = np.asarray(dense.embed_inputs(
        params, cfg, jnp.asarray(prompt)[None], None, None)[0])

    n_dec, width = 4, 16
    T = n_dec + width
    owned = np.asarray([3], np.int32)

    def blank(T):
        return dict(
            token_ids=np.zeros((T,), np.int32),
            x_prefill=np.zeros((T, cfg.d_model), x.dtype),
            is_prefill=np.zeros((T,), bool),
            positions=np.zeros((T,), np.int32),
            write_block=np.full((T,), trash, np.int32),
            write_slot=np.zeros((T,), np.int32),
            tables=np.full((T, max_blocks), trash, np.int32),
            lengths=np.ones((T,), np.int32),
            temperature=np.zeros((T,), np.float32),
            top_p=np.ones((T,), np.float32),
            seeds=np.zeros((T,), np.uint32),
            sample_pos=np.zeros((T,), np.int32))

    b = blank(T)
    rows = slice(n_dec, n_dec + S)
    p = np.arange(S)
    b["is_prefill"][rows] = True
    b["x_prefill"][rows] = x
    b["positions"][rows] = p
    b["write_block"][rows] = owned[p // bs]
    b["write_slot"][rows] = p % bs
    b["tables"][rows, :1] = owned
    b["lengths"][rows] = p + 1
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    batch["k_pool"], batch["v_pool"] = k_pool, v_pool
    packed = jax.jit(lambda pr, bb: dense.packed_step_core(pr, cfg, bb))
    logits, nxt, ks, vs = packed(params, batch)
    last = n_dec + S - 1
    np.testing.assert_array_equal(np.asarray(logits[last]),
                                  np.asarray(ref_logits[0]))
    np.testing.assert_array_equal(np.asarray(ks[:, 3, :S]),
                                  np.asarray(rks[:, 0].astype(ks.dtype)))

    # decode row continuing the sequence, packed wider than the old step
    old_batch = {"tokens": jnp.asarray([int(nxt[last]), 0], jnp.int32),
                 "positions": jnp.asarray([S, 0], jnp.int32),
                 "active": jnp.asarray([True, False]),
                 "block_tables": jnp.asarray(
                     np.stack([np.concatenate([owned, np.full((7,), trash,
                                                              np.int32)]),
                               np.full((8,), trash, np.int32)])),
                 "k_pool": ks, "v_pool": vs}
    ol, onxt, _, _ = jax.jit(
        lambda pr, bb: dense.paged_decode_step(pr, cfg, bb, force_ref=True)
    )(params, old_batch)

    b2 = blank(T)
    b2["token_ids"][0] = int(nxt[last])
    b2["positions"][0] = S
    b2["write_block"][0] = owned[S // bs]
    b2["write_slot"][0] = S % bs
    b2["tables"][0, :1] = owned
    b2["lengths"][0] = S + 1
    batch2 = {k: jnp.asarray(v) for k, v in b2.items()}
    batch2["k_pool"], batch2["v_pool"] = ks, vs
    pl, pnxt, _, _ = packed(params, batch2)
    np.testing.assert_array_equal(np.asarray(pl[0]), np.asarray(ol[0]))
    assert int(pnxt[0]) == int(onxt[0])


# ================================================ engine-level greedy parity
def test_packed_vs_two_program_greedy_bit_identical(text_setup):
    """Acceptance: the packed runner's greedy streams == the pre-refactor
    two-program path, across short (single-chunk), long (multi-chunk) and
    mid prompts decoding concurrently."""
    cfg, params = text_setup
    prompts = _prompts(cfg, (12, 90, 40))
    got, eng_p = _serve(cfg, params, prompts, runner="packed")
    want, eng_t = _serve(cfg, params, prompts, runner="two_program")
    assert got == want
    assert eng_p.stats["packed_steps"] > 0
    assert eng_t.stats["packed_steps"] == 0
    # identical iteration structure, one program instead of 1 + n_chunks
    for key in ("decode_steps", "decode_tokens", "prefill_chunks",
                "prefill_completions"):
        assert eng_p.stats[key] == eng_t.stats[key], key
    assert eng_p.kv_mgr.used_blocks == 0


def test_packed_nucleus_matches_two_program(text_setup):
    """Same kernels (ref), bit-identical logits -> the seeded nucleus
    sampler draws identical tokens through the packed path."""
    cfg, params = text_setup
    prompts = _prompts(cfg, (20, 70), seed=9)

    def serve(runner):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=2, kv_blocks=64, max_seq_len=256, prefill_chunk=32,
            runner=runner))
        eng.start()
        try:
            for i, p in enumerate(prompts):
                eng.submit(ServeRequest(
                    req_id=i + 1, prompt=p.copy(), max_new_tokens=5,
                    sampling=SamplingParams(temperature=0.8, top_p=0.9,
                                            seed=41 + i)))
            return [eng.result(i + 1, timeout=300).tokens
                    for i in range(len(prompts))]
        finally:
            eng.stop()

    assert serve("packed") == serve("two_program")


@pytest.mark.cluster
def test_cluster_packed_parity_and_topologies(text_setup):
    """ClusterEngine runs the packed runner per instance — greedy streams
    match the two-program EPDEngine on aggregated AND disaggregated
    topologies (P-only instances run a zero-slot runner; D-only
    instances drive the packed program decode-only)."""
    cfg, params = text_setup
    prompts = _prompts(cfg, (12, 90, 40), seed=5)
    want, _ = _serve(cfg, params, prompts, runner="two_program")
    for topo in ("1EPD", "1P1D"):
        got, clu = _serve(cfg, params, prompts, engine_cls=ClusterEngine,
                          topo=topo, runner="packed")
        assert got == want, topo
        assert clu.stats["packed_steps"] > 0
        if topo == "1P1D":
            assert clu.stats["pd_migrations"] == len(prompts)


# =========================================== preemption + compile stability
def test_packed_preemption_replay_identical(text_setup):
    """OutOfBlocks preemption through the packed path: victims replay
    deterministically — tight-pool output == ample-pool output."""
    cfg, params = text_setup
    prompts = _prompts(cfg, (44, 44), seed=4)
    outs = {}
    for name, blocks in (("ample", 64), ("tight", 7)):
        out, eng = _serve(cfg, params, prompts, max_new=20,
                          kv_blocks=blocks, kv_block_size=16,
                          max_seq_len=112, prefill_chunk=16,
                          runner="packed")
        outs[name] = out
        if name == "tight":
            assert eng.stats["preemptions"] >= 1
        assert eng.kv_mgr.used_blocks == 0
    assert outs["ample"] == outs["tight"]


def test_bucketed_shapes_never_recompile_mid_run(text_setup):
    """Warm-up traffic visits every bucket; afterwards a second identical
    wave must not add ONE compiled shape (``packed_compiles`` is the
    packed program's distinct-shape count surfaced in ServeStats)."""
    cfg, params = text_setup
    ecfg = EngineConfig(decode_batch=2, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=16, step_token_budget=34,
                        runner="packed")
    eng = EPDEngine(cfg, params, ecfg)
    n_buckets = len(eng.decode_stage.buckets)
    n_widths = len(eng.decode_stage.table_buckets)

    def wave(base):
        for i, p in enumerate(_prompts(cfg, (12, 60, 33, 90), seed=8)):
            eng.submit(ServeRequest(req_id=base + i, prompt=p.copy(),
                                    max_new_tokens=5))
        for i in range(4):
            eng.result(base + i, timeout=300)

    eng.start()
    try:
        wave(1)
        warm = eng.stats["packed_compiles"]
        # shapes are (token bucket, table-width bucket) pairs now; +1 is
        # the chunkless decode token shape
        assert 0 < warm <= (n_buckets + 1) * n_widths
        wave(100)
        assert eng.stats["packed_compiles"] == warm
        assert eng.stats["packed_steps"] > 0
    finally:
        eng.stop()


def test_packed_table_width_buckets_no_recompile(text_setup):
    """Block-table width bucketing: short sequences run with a narrow
    table (not ``max_blocks``), widths come from the static ladder, and a
    second identical wave adds ZERO compiled shapes — widths can never
    drive a mid-run recompile."""
    cfg, params = text_setup
    ecfg = EngineConfig(decode_batch=2, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=16, runner="packed")
    eng = EPDEngine(cfg, params, ecfg)
    runner = eng.decode_stage
    max_blocks = eng._kv.max_blocks

    def wave(base):
        for i, p in enumerate(_prompts(cfg, (12, 150, 40), seed=9)):
            eng.submit(ServeRequest(req_id=base + i, prompt=p.copy(),
                                    max_new_tokens=4))
        for i in range(3):
            eng.result(base + i, timeout=300)

    eng.start()
    try:
        wave(1)
        widths = set(runner.table_widths_used)
        assert widths, "no packed step ran"
        assert all(w in runner.table_buckets for w in widths)
        # the short-prompt iterations must NOT have paid full width
        assert min(widths) < max_blocks
        assert eng.stats["packed_table_widths"] == len(widths)
        warm = eng.stats["packed_compiles"]
        wave(100)
        assert runner.table_widths_used == widths
        assert eng.stats["packed_compiles"] == warm
    finally:
        eng.stop()


# ======================================================= backend smoke tests
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_engine_backend_smoke(text_setup, backend):
    """The same engine test under both registered backends: chunked
    prefill + decode complete, deterministically, through the packed
    runner (pallas runs its kernels in interpret mode off-TPU)."""
    cfg, params = text_setup
    prompts = _prompts(cfg, (20,), seed=6)
    runs = []
    for _ in range(2):
        out, eng = _serve(cfg, params, prompts, max_new=3,
                          decode_batch=1, kv_blocks=16, max_seq_len=64,
                          prefill_chunk=16, attn_backend=backend)
        runs.append(out)
        assert eng.backend.name == backend
        assert eng.stats["prefill_chunks"] >= 2    # chunked path exercised
        assert eng.stats["packed_steps"] > 0
        assert len(out[0]) == 3
    assert runs[0] == runs[1]


def test_env_backend_engine_smoke(text_setup):
    """ci.sh runs this with REPRO_ATTN_BACKEND=pallas: the engine picks
    the env-selected backend up with no config change (and under the
    default environment it simply runs the platform default)."""
    cfg, params = text_setup
    expect = os.environ.get(ENV_VAR) or (
        "pallas" if jax.default_backend() == "tpu" else "ref")
    out, eng = _serve(cfg, params, _prompts(cfg, (20,), seed=6), max_new=2,
                      decode_batch=1, kv_blocks=16, max_seq_len=64,
                      prefill_chunk=16)
    assert eng.backend.name == expect
    assert len(out[0]) == 2


# =============================================== backend math cross-checks
def test_backend_entry_points_close():
    """Every pallas entry point agrees with its ref oracle to fp32
    rounding (the engines assert bit-identity only WITHIN a backend)."""
    ref, pal = get_backend("ref"), get_backend("pallas")
    rng = np.random.default_rng(5)
    B, C, H, K, hd, Pmax = 1, 16, 8, 2, 64, 64
    f = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = f(B, C, H, hd), f(B, C, K, hd), f(B, C, K, hd)
    kp, vp = f(B, Pmax, K, hd), f(B, Pmax, K, hd)
    for prev_len in (0, 32, 48):
        np.testing.assert_allclose(
            np.asarray(ref.prefix_chunk_attention(q, k, v, kp, vp,
                                                  jnp.int32(prev_len))),
            np.asarray(jax.jit(pal.prefix_chunk_attention)(
                q, k, v, kp, vp, jnp.int32(prev_len))),
            atol=2e-5)
    qq, kk, vv = f(B, 48, H, hd), f(B, 48, K, hd), f(B, 48, K, hd)
    np.testing.assert_allclose(
        np.asarray(ref.prefill_attention(qq, kk, vv, causal=True)),
        np.asarray(jax.jit(lambda a, b, c: pal.prefill_attention(
            a, b, c, causal=True))(qq, kk, vv)),
        atol=2e-5)
    qd, kc, vc = f(2, H, hd), f(2, 64, K, hd), f(2, 64, K, hd)
    ln = jnp.asarray([40, 17], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ref.decode_attention(qd, kc, vc, ln)),
        np.asarray(jax.jit(pal.decode_attention)(qd, kc, vc, ln)),
        atol=2e-5)
