"""In-memory fake engine speaking the duck-typed frontend surface.

Lets the load-balancer and gateway tests exercise routing, failover and
overload deterministically without booting a real model: a ``FakeEngine``
completes (or deliberately never completes) requests on demand, and its
health/pressure readings are plain attributes the test flips."""
from __future__ import annotations

import threading
import time
from typing import Iterator

from repro.configs import get_config
from repro.serving.types import (FinishReason, RequestState, RequestTimeout,
                                 ServeRequest)


class FakeHandle:
    """Mirrors ``RequestHandle.result()/stream()`` over a bare request."""

    def __init__(self, req: ServeRequest, engine: "FakeEngine"):
        self.req = req
        self.engine = engine

    @property
    def req_id(self) -> int:
        return self.req.req_id

    def result(self, timeout: float = 300.0) -> ServeRequest:
        deadline = time.time() + timeout
        with self.req._cv:
            while not self.req.finished:
                remaining = deadline - time.time()
                if remaining <= 0:
                    raise RequestTimeout(self.req.req_id, timeout)
                self.req._cv.wait(remaining)
        return self.req

    def stream(self, timeout: float = 300.0) -> Iterator[int]:
        i = 0
        deadline = time.time() + timeout
        while True:
            with self.req._cv:
                while len(self.req.tokens) <= i and not self.req.finished:
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        raise RequestTimeout(self.req.req_id, timeout)
                    self.req._cv.wait(remaining)
                toks = list(self.req.tokens)
                finished = self.req.finished
                error = self.req.error
            while i < len(toks):
                yield toks[i]
                i += 1
            if finished:
                if error is not None:
                    raise RuntimeError(
                        f"request {self.req.req_id} failed: {error}")
                return


def finish(req: ServeRequest, tokens=(1, 2, 3)) -> None:
    """Walk a request to DONE emitting ``tokens`` (legal-lifecycle walk)."""
    if req.state is RequestState.QUEUED:
        req.advance(RequestState.PREFILLING)
    if req.state is RequestState.PREFILLING:
        req.advance(RequestState.DECODING)
    for t in tokens:
        req.emit(t)
    req.mark_done(FinishReason.LENGTH)


class FakeEngine:
    """Frontend-surface stub: ``cfg``/``submit``/``abort``/``collect``/
    ``stats``/``health``/``queue_depth``/``kv_block_counts``/
    ``current_roles`` — everything the LB and gateway consume."""

    def __init__(self, name: str = "fake", *, auto_complete: bool = True,
                 tokens=(1, 2, 3), roles=("EPD",), ok: bool = True,
                 depth: int = 0, kv=(64, 64), arch: str = "pixtral-12b"):
        self.name = name
        self.cfg = get_config(arch).reduced()
        self.auto_complete = auto_complete
        self.tokens = tuple(tokens)
        self.roles = list(roles)
        self.ok = ok                    # health-probe verdict (test flips it)
        self.depth = depth
        self.kv = kv
        self.handles: dict[int, FakeHandle] = {}
        self.aborted: list[tuple[int, str]] = []
        self.collected: list[int] = []
        self._lock = threading.Lock()

    # --------------------------------------------------- frontend surface
    def submit(self, req: ServeRequest) -> FakeHandle:
        h = FakeHandle(req, self)
        with self._lock:
            self.handles[req.req_id] = h
        if self.auto_complete:
            finish(req, self.tokens)
        return h

    def abort(self, req_id: int, reason: str = "aborted by client") -> bool:
        with self._lock:
            h = self.handles.get(req_id)
        if h is None or h.req.finished:
            return False
        self.aborted.append((req_id, reason))
        return h.req.mark_failed(reason)

    def collect(self, req_id: int) -> None:
        self.collected.append(req_id)
        with self._lock:
            self.handles.pop(req_id, None)

    def health(self) -> dict:
        if not self.ok:
            raise RuntimeError(f"{self.name} probe failed")
        return {"ok": True, "running": True}

    def queue_depth(self) -> int:
        return self.depth

    def kv_block_counts(self):
        return self.kv

    def current_roles(self):
        return list(self.roles)

    @property
    def stats(self) -> dict:
        return {"submitted": len(self.handles) + len(self.collected),
                "aborts": len(self.aborted)}
