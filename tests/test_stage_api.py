"""Stage-graph serving API: typed stages run standalone (no threads),
ψ_EP MMTokenCache hit/miss/eviction + encode-skip, sampling end-to-end,
streaming-vs-result parity, and paged-vs-dense parity through the
OpenAI-shaped frontend.
"""
import ast
import os
import subprocess
import sys
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro.configs import get_config
from repro.models import build_model, dense
from repro.serving import (EPDEngine, EngineConfig, FinishReason,
                           MMTokenCache, PsiEP, PsiPD, RequestState,
                           SamplingParams, ServeRequest)
from repro.serving.api import (_toy_tokenize, build_chat_response,
                               chat_completion, parse_chat_request)
from repro.serving.stages import (DenseDecodeStage, DensePrefillStage,
                                  EncodeStage, PagedDecodeStage,
                                  PagedKVState, PagedPrefillStage,
                                  ServeStats)


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _mm(cfg, seed, groups=2):
    rng = np.random.default_rng(seed)
    M = groups * cfg.modality.tokens_per_item
    return (rng.standard_normal((M, cfg.modality.enc_d_model))
            .astype(np.float32) * 0.1)


# ------------------------------------------------------------- lifecycle
def test_request_lifecycle_transitions():
    req = ServeRequest(req_id=1, prompt=np.arange(4, dtype=np.int32))
    assert req.state is RequestState.QUEUED
    req.advance(RequestState.ENCODING)
    req.advance(RequestState.PREFILLING)
    req.advance(RequestState.DECODING)
    req.advance(RequestState.PREFILLING)      # preemption requeues via P
    req.advance(RequestState.DECODING)
    req.mark_done(FinishReason.LENGTH)
    assert req.finished and req.finish_reason is FinishReason.LENGTH
    with pytest.raises(ValueError):
        req.advance(RequestState.ENCODING)    # DONE is terminal


def test_illegal_transition_rejected():
    req = ServeRequest(req_id=2, prompt=np.arange(4, dtype=np.int32))
    with pytest.raises(ValueError):
        req.advance(RequestState.DECODING)    # must prefill first


# ----------------------------------------------------------- MMTokenCache
def test_mm_cache_hit_miss_and_lru_eviction():
    cache = MMTokenCache(capacity=2)
    a, b, c = (np.full((2, 3), v, np.float32) for v in (1.0, 2.0, 3.0))
    ka, kb, kc = (MMTokenCache.content_key(x) for x in (a, b, c))
    assert len({ka, kb, kc}) == 3
    assert cache.get(ka) is None and cache.misses == 1
    cache.put(ka, a)
    cache.put(kb, b)
    assert cache.get(ka) is a and cache.hits == 1
    cache.put(kc, c)                          # evicts LRU entry = b
    assert cache.get(kb) is None
    assert cache.get(ka) is not None and cache.get(kc) is not None
    assert cache.evictions == 1 and len(cache) == 2


def test_mm_cache_key_is_content_based():
    a = np.random.default_rng(0).standard_normal((3, 4)).astype(np.float32)
    assert MMTokenCache.content_key(a) == MMTokenCache.content_key(a.copy())
    assert MMTokenCache.content_key(a) != MMTokenCache.content_key(a + 1e-3)
    # shape matters, not just bytes
    assert (MMTokenCache.content_key(a) !=
            MMTokenCache.content_key(a.reshape(4, 3)))


# ------------------------------------------------- stages without threads
def test_encode_stage_shards_merge_losslessly(vlm_setup):
    cfg, params = vlm_setup
    model = build_model(cfg)
    stage = EncodeStage(model, cfg, params, n_workers=2)
    mm = _mm(cfg, seed=3)
    M = mm.shape[0]
    req = ServeRequest(req_id=10, prompt=np.arange(8, dtype=np.int32),
                       mm_embeds=mm,
                       mm_positions=np.arange(1, M + 1, dtype=np.int32))
    shards = stage.plan_shards(req)
    assert len(shards) == 2                   # two patch groups, two workers
    assert sorted(np.concatenate(shards).tolist()) == list(range(M))
    psi = PsiEP(MMTokenCache(4))
    merged = None
    for sid, idx in enumerate(shards):
        out = psi.add_shard(req, sid, len(shards), idx,
                            stage.encode_shard(req, idx))
        if out is not None:
            merged = out
    assert merged is not None and stage.shards_run == 2
    whole = np.asarray(stage.encode_fn(params, jnp.asarray(mm)[None])[0])
    np.testing.assert_allclose(merged, whole, rtol=2e-2, atol=2e-2)


def test_paged_prefill_and_decode_stages_standalone(vlm_setup):
    """P and D paged stages drive a request to completion synchronously."""
    cfg, params = vlm_setup
    model = build_model(cfg)
    ecfg = EngineConfig(decode_batch=2, kv_blocks=32, max_seq_len=64)
    stats = ServeStats()
    kv = PagedKVState(model, cfg, ecfg)
    pstage = PagedPrefillStage(model, cfg, params, ecfg, stats, kv)
    finished = []
    dstage = PagedDecodeStage(model, cfg, params, ecfg, stats, kv,
                              on_finish=finished.append,
                              on_requeue=lambda r, m: None)
    req = ServeRequest(req_id=11,
                       prompt=np.arange(8, dtype=np.int32) % cfg.vocab,
                       max_new_tokens=3)
    handoff = pstage.prefill(req, None)
    assert handoff is not None and len(req.tokens) == 1
    psi = PsiPD()
    psi.send(handoff)
    for _ in range(10):
        if finished:
            break
        dstage.step(psi)
    assert [r.req_id for r in finished] == [11]
    assert len(req.tokens) == 3
    assert kv.mgr.used_blocks == 0            # blocks returned on finish
    assert stats.data["decode_steps"] > 0


def test_dense_prefill_and_decode_stages_standalone(vlm_setup):
    cfg, params = vlm_setup
    model = build_model(cfg)
    ecfg = EngineConfig(decode_batch=2, mode="dense")
    stats = ServeStats()
    pstage = DensePrefillStage(model, cfg, params, ecfg, stats)
    finished = []
    dstage = DenseDecodeStage(model, cfg, params, ecfg, stats,
                              on_finish=finished.append)
    req = ServeRequest(req_id=12,
                       prompt=np.arange(8, dtype=np.int32) % cfg.vocab,
                       max_new_tokens=3)
    psi = PsiPD()
    psi.send(pstage.prefill(req, None))
    for _ in range(10):
        if finished:
            break
        dstage.step(psi)
    assert [r.req_id for r in finished] == [12]
    assert len(req.tokens) == 3
    assert stats.live_cache_bytes == 0        # dense cache released


# --------------------------------------------------------------- sampling
def test_sample_tokens_greedy_and_nucleus():
    logits = jnp.asarray([[1.0, 3.0, 2.0, -1.0]] * 3)
    temps = jnp.asarray([0.0, 1.0, 1.0])
    top_ps = jnp.asarray([1.0, 1e-6, 0.9])
    seeds = jnp.asarray([0, 0, 123], jnp.uint32)
    pos = jnp.zeros((3,), jnp.int32)
    out = np.asarray(dense.sample_tokens(logits, temps, top_ps, seeds, pos))
    assert out[0] == 1                        # temperature 0 -> exact argmax
    assert out[1] == 1                        # top_p -> 0 keeps only top-1
    out2 = np.asarray(dense.sample_tokens(logits, temps, top_ps, seeds, pos))
    assert (out == out2).all()                # seeded draws are deterministic
    assert 0 <= out[2] < 4


def test_sampled_decode_is_seeded_deterministic(vlm_setup):
    """temperature>0 reruns with the same seed emit identical tokens, and
    the explicit temperature=0 path equals the default greedy path."""
    cfg, params = vlm_setup
    text = " ".join(f"w{i}" for i in range(10))
    sampled = {"messages": [{"role": "user", "content": text}],
               "max_tokens": 5, "temperature": 0.9, "top_p": 0.9, "seed": 7}
    greedy = {"messages": [{"role": "user", "content": text}],
              "max_tokens": 5}
    explicit0 = dict(greedy, temperature=0.0, top_p=1.0)
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=64, max_seq_len=128))
    eng.start()
    try:
        s1 = chat_completion(eng, sampled)["choices"][0]["token_ids"]
        s2 = chat_completion(eng, sampled)["choices"][0]["token_ids"]
        g1 = chat_completion(eng, greedy)["choices"][0]["token_ids"]
        g2 = chat_completion(eng, explicit0)["choices"][0]["token_ids"]
    finally:
        eng.stop()
    assert s1 == s2 and len(s1) == 5
    assert g1 == g2                           # temp=0 is bit-identical greedy


def test_sampling_params_carried_from_payload(vlm_setup):
    cfg, _ = vlm_setup
    req = parse_chat_request(cfg, {
        "messages": [{"role": "user", "content": "a b c"}],
        "temperature": 0.7, "top_p": 0.9, "seed": 3})
    assert req.sampling == SamplingParams(temperature=0.7, top_p=0.9, seed=3)


# -------------------------------------------------------------- streaming
def test_stream_matches_result(vlm_setup):
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=64, max_seq_len=128))
    eng.start()
    try:
        req = ServeRequest(req_id=501,
                           prompt=np.arange(10, dtype=np.int32) % cfg.vocab,
                           max_new_tokens=5)
        handle = eng.submit(req)
        streamed = list(handle.stream(timeout=300))
        out = handle.result(timeout=300)
    finally:
        eng.stop()
    assert streamed == out.tokens and len(streamed) == 5
    assert out.state is RequestState.DONE
    assert out.finish_reason is FinishReason.LENGTH


# ----------------------------------------------------- ψ_EP encode skip
def test_mm_cache_skips_encode_on_repeat(vlm_setup):
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=64, max_seq_len=128))
    eng.start()
    mm = _mm(cfg, seed=5)
    M = mm.shape[0]
    prompt = np.arange(M + 6, dtype=np.int32) % cfg.vocab

    def mk(rid):
        return ServeRequest(req_id=rid, prompt=prompt.copy(),
                            mm_embeds=mm.copy(),
                            mm_positions=np.arange(1, M + 1, dtype=np.int32),
                            max_new_tokens=4)
    try:
        eng.submit(mk(601))
        out1 = eng.result(601, timeout=300)
        shards_after_first = eng.encode_stage.shards_run
        assert shards_after_first > 0 and not out1.mm_cache_hit
        eng.submit(mk(602))
        out2 = eng.result(602, timeout=300)
    finally:
        eng.stop()
    assert out2.mm_cache_hit
    # E ran ZERO shards on the hit path, yet the output is token-identical
    assert eng.encode_stage.shards_run == shards_after_first
    assert out2.tokens == out1.tokens
    assert eng.stats["mm_cache_hits"] == 1
    assert eng.stats["mm_cache_misses"] == 1
    assert eng.mm_cache.hits == 1


def test_disabled_mm_cache_never_hits(vlm_setup):
    """mm_cache_entries=0 turns ψ_EP caching off: repeats re-encode."""
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=64, max_seq_len=128,
        mm_cache_entries=0))
    eng.start()
    mm = _mm(cfg, seed=6)
    M = mm.shape[0]
    prompt = np.arange(M + 6, dtype=np.int32) % cfg.vocab

    def mk(rid):
        return ServeRequest(req_id=rid, prompt=prompt.copy(),
                            mm_embeds=mm.copy(),
                            mm_positions=np.arange(1, M + 1, dtype=np.int32),
                            max_new_tokens=2)
    try:
        eng.submit(mk(701))
        eng.result(701, timeout=300)
        shards_first = eng.encode_stage.shards_run
        eng.submit(mk(702))
        out2 = eng.result(702, timeout=300)
    finally:
        eng.stop()
    assert not out2.mm_cache_hit
    assert eng.encode_stage.shards_run == 2 * shards_first
    assert eng.stats["mm_cache_hits"] == 0 and len(eng.mm_cache) == 0


def test_oversized_seed_rejected():
    with pytest.raises(ValueError, match="seed"):
        SamplingParams(seed=2 ** 32).validate()
    from repro.serving.api import APIError
    cfg = get_config("pixtral-12b").reduced()
    with pytest.raises(APIError, match="seed"):
        parse_chat_request(cfg, {
            "messages": [{"role": "user", "content": "x"}],
            "seed": 2 ** 32})


def test_result_releases_handle_registry(vlm_setup):
    """Finished requests must not accumulate in the engine forever."""
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=64, max_seq_len=128))
    eng.start()
    try:
        req = ServeRequest(req_id=801,
                           prompt=np.arange(6, dtype=np.int32) % cfg.vocab,
                           max_new_tokens=2)
        handle = eng.submit(req)
        out = handle.result(timeout=300)
        # a handle kept by the caller still streams after collection
        assert list(handle.stream(timeout=10)) == out.tokens
    finally:
        eng.stop()
    assert eng._handles == {} and eng._done == {}


# ------------------------------------------------------------ OpenAI shape
def test_chat_completion_shape_and_usage(vlm_setup):
    cfg, params = vlm_setup
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=64, max_seq_len=128))
    eng.start()
    try:
        resp = chat_completion(eng, {
            "messages": [{"role": "user",
                          "content": "the quick brown fox jumps"}],
            "max_tokens": 4})
    finally:
        eng.stop()
    assert resp["object"] == "chat.completion"
    assert resp["model"] == cfg.name
    choice = resp["choices"][0]
    assert choice["finish_reason"] == "length"
    assert len(choice["token_ids"]) == 4
    assert choice["message"]["content"].split() == \
        [str(t) for t in choice["token_ids"]]
    assert resp["usage"] == {"prompt_tokens": 5, "completion_tokens": 4,
                             "total_tokens": 9}
    t = resp["timings"]
    assert t["ttft"] > 0 and t["n_preemptions"] == 0
    assert t["mm_cache_hit"] is False


def test_paged_dense_parity_through_api(vlm_setup):
    """Greedy paged and dense engines emit identical token_ids for the
    same multimodal payload via the OpenAI-shaped frontend."""
    cfg, params = vlm_setup
    mm = _mm(cfg, seed=9)
    payload = {"messages": [{"role": "user", "content": [
        {"type": "text",
         "text": " ".join(f"w{i}" for i in range(mm.shape[0] + 4))},
        {"type": "image_embedding", "embedding": mm.tolist()}]}],
        "max_tokens": 4}
    ids = {}
    for mode in ("paged", "dense"):
        eng = EPDEngine(cfg, params, EngineConfig(
            n_encode_workers=2, decode_batch=2, mode=mode,
            kv_blocks=128, max_seq_len=256))
        eng.start()
        try:
            ids[mode] = chat_completion(eng, payload)["choices"][0]["token_ids"]
        finally:
            eng.stop()
    assert ids["paged"] == ids["dense"] and len(ids["paged"]) == 4


# ---------------------------------------------------------- tokenization
def test_tokenizer_is_stable_across_processes():
    """crc32 tokenization must not vary per interpreter (hash() does)."""
    text, vocab = "the quick brown fox", 50_000
    toks = _toy_tokenize(text, vocab).tolist()
    # regression-pin against direct crc32 (seedless, process-independent)
    assert toks == [zlib.crc32(w.encode()) % (vocab - 3) + 2
                    for w in text.split()]
    src = os.path.dirname(os.path.abspath(next(iter(repro.__path__))))
    code = ("from repro.serving.api import _toy_tokenize; "
            f"print(_toy_tokenize({text!r}, {vocab}).tolist())")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True)
    assert ast.literal_eval(out.stdout.strip()) == toks
