"""Import shim: property tests skip (not error) when hypothesis is absent.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis imports when the package is installed; otherwise
``@given(...)`` marks the test as skipped and ``st.*``/``settings`` degrade
to inert stand-ins (their arguments are never executed).
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - exercised only without dep
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
