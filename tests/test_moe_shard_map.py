"""shard_map expert-parallel MoE (§Perf A4): exact match vs the grouped
dispatch path, finite gradients, correct all-to-all routing. Runs in a
subprocess with 8 virtual host devices (the XLA device-count flag must not
leak into the main test process)."""
import os
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.moe import moe_apply, moe_apply_shard_map, moe_init

cfg = get_config("qwen3-moe-30b-a3b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)
with mesh:
    y_ref, aux_ref = moe_apply(params, x, cfg, dispatch_groups=2)
    y_sm, aux_sm = jax.jit(
        lambda p, xx: moe_apply_shard_map(p, xx, cfg, mesh))(params, x)
err = float(jnp.max(jnp.abs(y_ref.astype(jnp.float32)
                            - np.asarray(y_sm, np.float32))))
assert err < 1e-2, f"output mismatch {err}"
assert abs(float(aux_ref) - float(aux_sm)) < 1e-5

def loss(p):
    y, aux = moe_apply_shard_map(p, x, cfg, mesh)
    return jnp.sum(y.astype(jnp.float32) ** 2) + aux

with mesh:
    g = jax.jit(jax.grad(loss))(params)
assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
           for l in jax.tree.leaves(g)), "non-finite grads"
print("OK", err)
"""


def test_shard_map_moe_matches_grouped_dispatch():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", CODE], env=env, capture_output=True,
        text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.startswith("OK")
