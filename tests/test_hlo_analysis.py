"""Trip-count-aware HLO analyzer: exact dot-FLOP counting through scans."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_single_dot_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = _compile_text(lambda x, y: x @ y, a, b)
    res = analyze_hlo(txt)
    assert res["flops"] == 2 * 64 * 32 * 128


def test_scan_multiplies_by_trip_count():
    L, d = 7, 32
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    res = analyze_hlo(_compile_text(f, ws, xs))
    assert res["flops"] == pytest.approx(L * 2 * 4 * d * d, rel=0.01)


def test_collectives_counted_with_trip_multiplier():
    # reuse the canonical sample produced in the dry-run path: a sharded
    # scan must report L x per-layer collective bytes
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((2, 4), ("data", "model"))
def f(w1, w2, x):
    def body(h, ws):
        a, b = ws
        return jnp.tanh(h @ a) @ b, None
    h, _ = jax.lax.scan(body, x, (w1, w2))
    return h
ws = jax.ShapeDtypeStruct((6, 256, 512), jnp.bfloat16)
ws2 = jax.ShapeDtypeStruct((6, 512, 256), jnp.bfloat16)
xs = jax.ShapeDtypeStruct((16, 256), jnp.bfloat16)
with mesh:
    sh1 = NamedSharding(mesh, P(None, "data", "model"))
    sh2 = NamedSharding(mesh, P(None, "model", None))
    shx = NamedSharding(mesh, P("data", None))
    c = jax.jit(f, in_shardings=(sh1, sh2, shx)).lower(ws, ws2, xs).compile()
res = analyze_hlo(c.as_text())
assert res["collectives"]["total"] > 0
per_layer = res["collectives"]["total"] / 6.0
assert per_layer == int(per_layer), res["collectives"]
print("OK", res["flops"], res["collectives"]["total"])
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=None,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.startswith("OK")
