"""Dynamic role switching + stress/soak coverage for ClusterEngine.

Covers the paper §3.2.4 mechanics on the REAL engine: demand-driven
re-roling (drain -> swap stage set/pools -> cooldown), concurrent
submits while a switch is in flight, ``stop()`` mid-switch, and
OutOfBlocks preemption on a two-instance decode pool. Structural
assertions only (states, counters, pool emptiness) — never wall-clock.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           RequestState, ServeRequest)

pytestmark = [pytest.mark.cluster, pytest.mark.slow]


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _text_req(cfg, rng, rid, max_new=6, prompt_len=8):
    return ServeRequest(
        req_id=rid,
        prompt=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
        max_new_tokens=max_new)


def _mm_req(cfg, rng, rid, max_new=2):
    M = 2 * cfg.modality.tokens_per_item
    return ServeRequest(
        req_id=rid, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
        mm_embeds=rng.standard_normal(
            (M, cfg.modality.enc_d_model)).astype(np.float32) * 0.1,
        mm_positions=np.arange(1, M + 1, dtype=np.int32),
        max_new_tokens=max_new)


def _wait(pred, timeout=30.0, dt=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(dt)
    return False


def test_demand_driven_role_switch(vlm_setup):
    """Encode-heavy -> decode-heavy shift re-roles an idle E instance to
    D (monitor driven deterministically via monitor_once); the switch
    drains first, logs occupancy, and no request strands."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(1)
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=2, max_new_tokens=24, decode_batch=2),
        ClusterConfig(spec="2E1P1D", role_switch=False))  # manual monitor
    clu.start()
    try:
        # phase 1: mm-heavy, short outputs — allocation is E-heavy, so
        # the monitor must NOT switch
        for i in range(4):
            clu.submit(_mm_req(cfg, rng, i, max_new=2))
        for i in range(4):
            clu.result(i, timeout=300)
        assert clu.monitor_once() is None
        # phase 2: text-only, long outputs — decode demand dominates
        ids = list(range(10, 26))
        for i in ids:
            clu.submit(_text_req(cfg, rng, i, max_new=24))
            time.sleep(0.005)
        switched = None
        for _ in range(200):
            switched = clu.monitor_once()
            if switched:
                break
            time.sleep(0.02)
        assert switched is not None, "no switch under decode-heavy load"
        iid, old, new = switched
        assert old == "E" and new == "D"
        outs = [clu.result(i, timeout=300) for i in ids]
        assert all(o.state is RequestState.DONE for o in outs)
        assert all(len(o.tokens) == 24 for o in outs)
        # the re-role completes once the donor drains
        assert _wait(lambda: clu.stats["role_switches"] >= 1)
        assert clu.current_roles().count("D") == 2
        assert clu.instances[iid].role == "D"
    finally:
        clu.stop()
    occ = clu.stats["role_seconds"]
    assert occ.get("E", 0) > 0 and occ.get("D", 0) > 0
    assert clu.switch_log and clu.switch_log[0][2:] == ("E", "D")


def test_concurrent_submits_during_live_switch(vlm_setup):
    """Requests submitted from several threads WHILE an instance drains
    and swaps roles all reach DONE — nothing misroutes or strands."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(2)
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=2, max_new_tokens=6, decode_batch=2),
        ClusterConfig(spec="2E1P1D"))
    clu.start()
    try:
        # force a switch directly (deterministic, no estimator needed)
        donor = clu.instances[0]
        assert donor.role == "E"
        prompts = [[_text_req(cfg, rng, 100 * t + i, max_new=6)
                    for i in range(6)] for t in range(1, 5)]
        donor.request_switch("D")

        def submitter(batch):
            for r in batch:
                clu.submit(r)
                time.sleep(0.002)

        threads = [threading.Thread(target=submitter, args=(b,))
                   for b in prompts]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = [clu.result(r.req_id, timeout=300)
                for b in prompts for r in b]
        assert all(o.state is RequestState.DONE for o in outs)
        assert all(len(o.tokens) == 6 for o in outs)
        assert _wait(lambda: clu.stats["role_switches"] >= 1)
        assert donor.role == "D" and donor.accepting
    finally:
        clu.stop()


def test_stop_mid_switch(vlm_setup):
    """stop() while a switch is draining: every handle reaches a terminal
    state promptly (DONE or FAILED), no deadlock, pools fully released."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(3)
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=2, max_new_tokens=16, decode_batch=2),
        ClusterConfig(spec="2E1P1D"))
    clu.start()
    reqs = [_text_req(cfg, rng, i, max_new=16) for i in range(8)] + \
           [_mm_req(cfg, rng, 50 + i, max_new=16) for i in range(4)]
    for r in reqs:
        clu.submit(r)
    clu.instances[0].request_switch("D")     # switch begins mid-traffic
    clu.stop()
    for r in reqs:
        assert r.finished, f"request {r.req_id} stranded in {r.state}"
        if r.state is RequestState.FAILED:
            assert "stopped" in (r.error or "")
    for inst in clu.instances:
        if inst.kv is not None:
            assert inst.kv.mgr.used_blocks == 0
        assert inst.load() == 0.0


def test_out_of_blocks_preemption_two_instance_decode_pool():
    """Decode pressure on a "1P2D" cluster: a victim is preempted
    (blocks freed, requeued through P, KV re-migrated) instead of
    crashing; every request completes with a full, correct output."""
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # prompt 15 -> 1 block (bs=16) at prefill; the first append crosses
    # into a second block, so a 3-block pool cannot hold two grown
    # sequences — with 4 requests over 2 D instances somebody preempts
    reqs = [ServeRequest(
        req_id=i, prompt=rng.integers(0, cfg.vocab, 15).astype(np.int32),
        max_new_tokens=8) for i in range(4)]
    clu = ClusterEngine(
        cfg, params,
        EngineConfig(n_encode_workers=1, max_new_tokens=8, decode_batch=2,
                     kv_blocks=3, kv_block_size=16, max_seq_len=64),
        "1P2D")
    clu.start()
    try:
        for r in reqs:
            clu.submit(r)
        outs = [clu.result(r.req_id, timeout=300) for r in reqs]
    finally:
        clu.stop()
    assert all(o.state is RequestState.DONE for o in outs)
    assert all(len(o.tokens) == 8 for o in outs)
    assert clu.stats["preemptions"] >= 1
    assert clu.stats["pd_migrations"] >= 4       # >= 1 per request + replays
    for inst in clu.instances:
        if inst.kv is not None:
            assert inst.kv.mgr.used_blocks == 0
