"""Load balancer: role/pressure routing, health EWMAs, failover.

Uses ``FakeEngine`` stubs so the routing and failover machinery is
exercised deterministically (pressure readings are plain attributes, the
health verdict is a flag the test flips, and requests complete — or
deliberately never complete — on demand). One test at the bottom runs a
real two-engine fleet end to end."""
import threading

import numpy as np
import pytest

from fake_engine import FakeEngine, finish
from repro.serving import LoadBalancer, ServeRequest
from repro.serving.lb import clone_request
from repro.serving.types import RequestState, RequestTimeout

_IDS = iter(range(30_000, 40_000))


def _req(mm=False, n_new=4):
    cfg = FakeEngine().cfg
    M = 4
    return ServeRequest(
        req_id=next(_IDS),
        prompt=np.arange(1, 6, dtype=np.int32),
        mm_embeds=(np.zeros((M, cfg.modality.enc_d_model), np.float32)
                   if mm else None),
        mm_positions=np.arange(1, M + 1, dtype=np.int32) if mm else None,
        max_new_tokens=n_new)


def _lb(*backends, **kw):
    lb = LoadBalancer(**kw)
    for i, b in enumerate(backends):
        lb.add_backend(b.name if b.name != "fake" else f"b{i}", b)
    return lb


def test_routes_to_lowest_queue_depth():
    a = FakeEngine("a", depth=5)
    b = FakeEngine("b", depth=0)
    lb = _lb(a, b)
    t = lb.submit(_req())
    assert t.backend.name == "b"
    assert t.result(timeout=5).tokens == [1, 2, 3]
    lb.collect(t.req_id)
    assert b.collected and not lb.tickets


def test_kv_pressure_steers_away_from_full_pool():
    a = FakeEngine("a", kv=(1, 64))     # nearly exhausted pool
    b = FakeEngine("b", kv=(64, 64))
    lb = _lb(a, b)
    assert lb.submit(_req()).backend.name == "b"


def test_probe_ewma_penalizes_limping_backend():
    a = FakeEngine("a")
    b = FakeEngine("b")
    lb = _lb(a, b, ewma_alpha=0.5)
    lb.backends["a"].observe_probe(500.0, ok=True, alpha=0.5)
    assert lb.backends["a"].ewma_ms == 500.0
    lb.backends["a"].observe_probe(100.0, ok=True, alpha=0.5)
    assert lb.backends["a"].ewma_ms == pytest.approx(300.0)
    assert lb.submit(_req()).backend.name == "b"


def test_mm_requests_require_encode_capable_backend():
    pd_only = FakeEngine("pd", roles=("PD",), depth=0)
    full = FakeEngine("full", roles=("EPD",), depth=10)
    lb = _lb(pd_only, full)
    # text goes to the idle PD backend, mm must take the loaded EPD one
    assert lb.submit(_req()).backend.name == "pd"
    assert lb.submit(_req(mm=True)).backend.name == "full"


def test_no_eligible_backend_raises():
    pd_only = FakeEngine("pd", roles=("PD",))
    lb = _lb(pd_only)
    with pytest.raises(RuntimeError, match="E-capable"):
        lb.submit(_req(mm=True))
    with pytest.raises(RuntimeError):
        LoadBalancer().submit(_req())       # empty fleet


def test_abort_routes_to_owning_backend():
    a = FakeEngine("a", auto_complete=False)
    lb = _lb(a)
    t = lb.submit(_req())
    assert lb.abort(t.req_id, "test") is True
    assert a.aborted == [(t.req_id, "test")]
    assert lb.abort(404_404) is False


def test_health_failover_resubmits_queued_request():
    """Backend dies mid-wait: its token-less request is transparently
    resubmitted and the caller's blocked ``result()`` returns the new
    backend's completion, never the transient failure."""
    a = FakeEngine("a", auto_complete=False)
    b = FakeEngine("b", tokens=(7, 8, 9))
    lb = _lb(a, b, max_failures=2)
    t = lb.submit(_req())
    assert t.backend.name == "a"

    box = {}
    waiter = threading.Thread(
        target=lambda: box.update(out=t.result(timeout=10)))
    waiter.start()

    a.ok = False
    for _ in range(2):
        lb.health_check_once()
    assert not lb.backends["a"].healthy
    assert lb.counters["backends_marked_unhealthy"] == 1
    assert lb.counters["failovers"] == 1

    waiter.join(timeout=10)
    assert not waiter.is_alive()
    assert box["out"].error is None
    assert box["out"].tokens == [7, 8, 9]
    assert t.backend.name == "b"
    assert t.generation == 1
    assert a.aborted        # the dead backend's copy was cancelled


def test_failover_stream_restarts_on_new_backend():
    a = FakeEngine("a", auto_complete=False)
    b = FakeEngine("b", tokens=(5, 6))
    lb = _lb(a, b, max_failures=1)
    t = lb.submit(_req())
    box = {}

    def consume():
        box["toks"] = list(t.stream(timeout=10))

    consumer = threading.Thread(target=consume)
    consumer.start()
    a.ok = False
    lb.health_check_once()
    consumer.join(timeout=10)
    assert not consumer.is_alive()
    assert box["toks"] == [5, 6]


def test_decoding_request_fails_on_failover():
    """A request that already delivered tokens cannot be re-homed; it
    surfaces as a failure instead of silently replaying the stream."""
    a = FakeEngine("a", auto_complete=False)
    b = FakeEngine("b")
    lb = _lb(a, b, max_failures=1)
    t = lb.submit(_req())
    # simulate partial progress: two tokens already streamed out
    t.req.advance(RequestState.PREFILLING)
    t.req.advance(RequestState.DECODING)
    t.req.emit(1)
    t.req.emit(2)
    a.ok = False
    lb.health_check_once()
    out = t.result(timeout=5)
    assert out.error is not None
    assert lb.counters["failovers"] == 0


def test_remove_backend_drains_and_fails_over():
    a = FakeEngine("a", auto_complete=False)
    b = FakeEngine("b", tokens=(4,))
    lb = _lb(a, b)
    t = lb.submit(_req())
    lb.remove_backend("a")
    assert "a" not in lb.backends
    out = t.result(timeout=5)
    assert out.error is None and out.tokens == [4]


def test_unhealthy_backend_recovers_on_ok_probe():
    a = FakeEngine("a")
    lb = _lb(a, max_failures=1)
    a.ok = False
    lb.health_check_once()
    assert not lb.backends["a"].healthy
    a.ok = True
    lb.health_check_once()
    assert lb.backends["a"].healthy


def test_raising_abort_does_not_kill_health_sweep():
    """Regression: ``_failover`` called ``dead.engine.abort`` unguarded
    per victim, so a *really* dead engine (abort raises) killed
    ``health_check_once`` — and with it the lb-health thread — leaving
    the rest of the fleet unprobed and later victims stranded."""
    class DeadEngine(FakeEngine):
        def abort(self, req_id, reason="aborted by client"):
            raise ConnectionError("engine process is gone")

    a = DeadEngine("a", auto_complete=False)
    b = FakeEngine("b", depth=8, tokens=(7,))     # busier: both route to a
    lb = _lb(a, b, max_failures=1)
    t1, t2 = lb.submit(_req()), lb.submit(_req())
    assert {t1.backend.name, t2.backend.name} == {"a"}
    a.ok = False
    lb.health_check_once()        # must not raise
    # BOTH victims were still resubmitted despite every abort raising
    assert lb.counters["failovers"] == 2
    assert lb.counters["failover_failures"] == 2      # the raising aborts
    assert t1.result(timeout=5).tokens == [7]
    assert t2.result(timeout=5).tokens == [7]
    # and the sweep survives to probe again
    lb.health_check_once()


def test_flapping_backend_needs_consecutive_ok_probes():
    """Regression: one ok probe re-admitted an unhealthy backend, so a
    flapping backend oscillated and re-triggered failover storms. Now
    recovery demands ``max_failures`` consecutive successes, and failed
    probes stay out of the latency EWMA."""
    a = FakeEngine("a")
    lb = _lb(a, max_failures=2)
    back = lb.backends["a"]
    back.observe_probe(10.0, ok=True, alpha=0.3)
    ewma_before = back.ewma_ms

    a.ok = False
    lb.health_check_once()
    lb.health_check_once()
    assert not back.healthy
    # failed probes (exceptions here) must not pollute the latency EWMA
    assert back.ewma_ms == ewma_before

    a.ok = True
    lb.health_check_once()        # 1 consecutive success: not yet
    assert not back.healthy
    a.ok = False
    lb.health_check_once()        # flap! success streak resets
    a.ok = True
    lb.health_check_once()
    assert not back.healthy       # streak is 1 again
    lb.health_check_once()
    assert back.healthy           # 2 consecutive successes: re-admitted


def test_result_timeout_clamped_and_reraises_caller_timeout():
    """Regression: ``LBTicket.result`` computed
    ``min(_FAILOVER_POLL, deadline - now)`` — negative once the deadline
    raced past — and re-raised ``RequestTimeout`` with the poll slice,
    not the caller's timeout. Pin both: every per-slice wait is >= 0 and
    the surfaced timeout is the caller's."""
    a = FakeEngine("a", auto_complete=False)
    lb = _lb(a)
    t = lb.submit(_req())
    seen = []
    inner = t.handle

    class Recorder:
        req = inner.req

        def result(self, timeout):
            seen.append(timeout)
            return inner.result(timeout=timeout)

    t.handle = Recorder()
    with pytest.raises(RequestTimeout) as ei:
        t.result(timeout=0.25)
    assert ei.value.waited == 0.25
    assert seen and all(w >= 0.0 for w in seen)


def test_clone_request_is_pristine():
    req = _req(mm=True)
    finish(req, (1, 2))
    clone = clone_request(req)
    assert clone.req_id == req.req_id
    assert clone.tokens == [] and not clone.finished
    assert np.array_equal(clone.prompt, req.prompt)
    assert clone.mm_embeds is req.mm_embeds


def test_health_and_stats_aggregation():
    a = FakeEngine("a", kv=(10, 64))
    b = FakeEngine("b", kv=(20, 64))
    lb = _lb(a, b)
    for _ in range(3):
        t = lb.submit(_req())
        t.result(timeout=5)
        lb.collect(t.req_id)
    h = lb.health()
    assert h["ok"] and len(h["backends"]) == 2
    names = {s["name"]: s for s in h["backends"]}
    assert names["a"]["kv_free_blocks"] == 10
    assert names["b"]["kv_total_blocks"] == 64
    s = lb.stats
    assert s["lb"]["routed"] == 3
    assert s["submitted"] == 3       # summed across backends


@pytest.mark.cluster
def test_real_two_engine_fleet_greedy_parity():
    """Two real engines behind the LB serve bit-identically to one."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EPDEngine, EngineConfig

    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    engines = [EPDEngine(cfg, params, EngineConfig(decode_batch=2))
               for _ in range(2)]
    for e in engines:
        e.start()
    lb = LoadBalancer()
    lb.add_backend("b0", engines[0])
    lb.add_backend("b1", engines[1])
    lb.start()
    try:
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
        ref = engines[0].submit(ServeRequest(
            req_id=50_000, prompt=prompt, max_new_tokens=6)).result(
                timeout=120)
        outs = []
        for i in range(4):
            t = lb.submit(ServeRequest(req_id=50_001 + i, prompt=prompt,
                                       max_new_tokens=6))
            outs.append(list(t.result(timeout=120).tokens))
            lb.collect(50_001 + i)
        assert all(o == list(ref.tokens) for o in outs)
        assert lb.counters["routed"] == 4
    finally:
        lb.stop()
        for e in engines:
            e.stop()
