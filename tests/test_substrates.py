"""Optimizer / checkpoint / data-pipeline / scheduler / costmodel units."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import A100_80G, NPU_910B3, TPU_V5E
from repro.core import costmodel as cm
from repro.core.scheduler import (FCFS, SJF, Assigner, LEAST_LOADED,
                                  ROUND_ROBIN, order_queue)
from repro.data.pipeline import TokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_lr)


# ------------------------------------------------------------------- optim
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    out_norm = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(out_norm) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.asarray(0))) < 0.2
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)
    assert float(cosine_lr(cfg, jnp.asarray(100))) < 0.01


def test_adamw_bf16_params_fp32_moments():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((8,), 0.5, jnp.bfloat16)}
    new_p, new_s, _ = adamw_update(params, grads, state,
                                   AdamWConfig(warmup_steps=1))
    assert new_p["w"].dtype == jnp.bfloat16
    assert int(new_s["step"]) == 1


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32)},
            "step": jnp.asarray(3, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert len(kept) == 2 and kept[-1] == "ckpt_00000004.npz"


# ------------------------------------------------------------------- data
def test_pipeline_deterministic():
    cfg = get_config("minitron-4b").reduced()
    p1 = TokenPipeline(cfg, batch=4, seq_len=32, seed=1)
    p2 = TokenPipeline(cfg, batch=4, seq_len=32, seed=1)
    b1, b2 = p1.batch_at(3), p2.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(4)["tokens"], b1["tokens"])


def test_pipeline_host_sharding():
    cfg = get_config("minitron-4b").reduced()
    shard0 = TokenPipeline(cfg, batch=4, seq_len=16, shard_id=0, n_shards=2)
    shard1 = TokenPipeline(cfg, batch=4, seq_len=16, shard_id=1, n_shards=2)
    b0, b1 = shard0.batch_at(0), shard1.batch_at(0)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_vlm_payload():
    cfg = get_config("pixtral-12b").reduced()
    b = TokenPipeline(cfg, batch=2, seq_len=64).batch_at(0)
    assert "mm_embeds" in b and "mm_positions" in b
    assert b["mm_embeds"].shape[0] == 2
    assert int(b["mm_positions"].max()) < 64


# --------------------------------------------------------------- scheduler
def test_round_robin_cycles():
    class I:
        accepting = True
        def load(self):
            return 0.0
    a = Assigner(ROUND_ROBIN)
    picks = [a.pick([I(), I(), I()]) for _ in range(6)]
    assert sorted(set(picks)) == [0, 1, 2]


def test_round_robin_starts_at_instance_zero():
    """Regression: pre-incrementing the cursor made the first pick alive[1],
    so instance 0 was systematically skipped at low request counts."""
    class I:
        accepting = True
        def load(self):
            return 0.0
    a = Assigner(ROUND_ROBIN)
    insts = [I(), I(), I()]
    assert [a.pick(insts) for _ in range(4)] == [0, 1, 2, 0]


def test_least_loaded_picks_min():
    class I:
        def __init__(self, l):
            self._l = l
            self.accepting = True
        def load(self):
            return self._l
    insts = [I(5.0), I(1.0), I(3.0)]
    assert Assigner(LEAST_LOADED).pick(insts) == 1


def test_sjf_orders_by_estimate():
    q = [3, 1, 2]
    assert order_queue(q, SJF, est=lambda j: j) == [1, 2, 3]
    assert order_queue(q, FCFS, est=lambda j: j) == [3, 1, 2]


# --------------------------------------------------------------- costmodel
def test_decode_is_bandwidth_bound_prefill_compute_bound():
    cfg = get_config("internvl2-8b")
    by = cm.weights_bytes(cfg, include_encoder=False)
    t_dec = cm.decode_step_time(cfg, A100_80G, context=1024, batch=1)
    assert t_dec >= by / (A100_80G.hbm_bw)  # at least the weight read
    fl = cm.prefill_flops(cfg, 4096)
    t_pre = cm.prefill_time(cfg, A100_80G, 4096)
    assert t_pre >= fl / (A100_80G.peak_flops)


def test_irp_speedup_near_linear():
    cfg = get_config("minicpm-v-2.6")
    t1 = cm.encode_time(cfg, A100_80G, n_patches=20, chips=1)
    t5 = cm.encode_time(cfg, A100_80G, n_patches=20, chips=5)
    assert 3.0 < t1 / t5 <= 5.1


def test_npu_encode_heavier_than_gpu():
    """App F.1: encode-to-prefill latency ratio higher on NPU."""
    cfg = get_config("internvl2-8b")
    r_gpu = cm.encode_time(cfg, A100_80G, 26) / cm.prefill_time(
        cfg, A100_80G, 26 * 256 + 22)
    r_npu = cm.encode_time(cfg, NPU_910B3, 26) / cm.prefill_time(
        cfg, NPU_910B3, 26 * 256 + 22)
    assert r_npu > r_gpu * 1.05


def test_minicpm_fewer_prefill_tokens_than_internvl():
    """§4.1: MiniCPM compresses image tokens; InternVL is prefill-heavy."""
    mini, ivl = get_config("minicpm-v-2.6"), get_config("internvl2-8b")
    assert mini.modality.tokens_per_item < ivl.modality.tokens_per_item


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8))
def test_encode_time_monotone_in_patches(n_patches, chips):
    cfg = get_config("minicpm-v-2.6")
    t1 = cm.encode_time(cfg, TPU_V5E, n_patches, chips=chips)
    t2 = cm.encode_time(cfg, TPU_V5E, n_patches + 1, chips=chips)
    assert t2 >= t1
