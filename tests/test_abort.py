"""Request cancellation: ``EngineBase.abort`` + ``RequestTimeout``.

The abort contract (gateway disconnects depend on it): the request
reaches FAILED promptly, every waiter (``result()``/``stream()``) wakes,
the request's KV blocks return to the pool via the stage sweeps (the
free-block count recovers to its pre-request baseline), and the engine
keeps serving unrelated requests bit-identically."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EPDEngine, EngineConfig, RequestTimeout,
                           ServeRequest)

LONG = 64          # enough decode steps to reliably abort mid-flight


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params = setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=64))
    eng.start()
    yield cfg, eng
    eng.stop()


_IDS = iter(range(10_000, 20_000))


def _req(cfg, n_new=LONG, mm=False, seed=0):
    rng = np.random.default_rng(seed)
    M = cfg.modality.tokens_per_item
    return ServeRequest(
        req_id=next(_IDS),
        prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
        mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                   .astype(np.float32) * 0.1) if mm else None,
        mm_positions=np.arange(1, M + 1, dtype=np.int32) if mm else None,
        max_new_tokens=n_new)


def _wait_free(eng, baseline, timeout=30.0):
    """Block until the pool's free-block count recovers to ``baseline``
    (abort frees via stage sweeps, so recovery is asynchronous)."""
    deadline = time.time() + timeout
    while time.time() < deadline:
        free, _ = eng.kv_block_counts()
        if free == baseline:
            return free
        time.sleep(0.05)
    return eng.kv_block_counts()[0]


def _quiesce(eng, timeout=30.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        free, total = eng.kv_block_counts()
        if free == total and eng.queue_depth() == 0:
            return free
        time.sleep(0.05)
    raise AssertionError(f"engine did not quiesce: {eng.kv_block_counts()}, "
                         f"depth={eng.queue_depth()}")


def test_abort_mid_stream_releases_blocks_and_unblocks(engine):
    cfg, eng = engine
    free0 = _quiesce(eng)
    handle = eng.submit(_req(cfg))
    got = []
    with pytest.raises(RuntimeError, match="abort"):
        for tok in handle.stream(timeout=60):
            got.append(tok)
            if len(got) == 3:
                assert eng.abort(handle.req_id) is True
    assert len(got) >= 3
    out = handle.result(timeout=30)
    assert out.error is not None and "abort" in out.error
    assert len(out.tokens) < LONG          # cancelled before completion
    assert _wait_free(eng, free0) == free0  # KV blocks back in the pool
    # double-abort of a finished request is a no-op
    assert eng.abort(handle.req_id) is False
    eng.collect(handle.req_id)
    assert eng.stats["aborts"] >= 1


def test_abort_unknown_request(engine):
    _, eng = engine
    assert eng.abort(999_999) is False


def test_abort_unblocks_concurrent_result_waiter(engine):
    cfg, eng = engine
    handle = eng.submit(_req(cfg))
    box = {}

    def waiter():
        box["out"] = handle.result(timeout=60)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.2)
    eng.abort(handle.req_id, "test abort")
    t.join(timeout=10)
    assert not t.is_alive(), "result() waiter not woken by abort"
    assert box["out"].error == "test abort"


def test_engine_serves_identically_after_abort(engine):
    cfg, eng = engine
    ref = eng.submit(_req(cfg, n_new=6, seed=7)).result(timeout=120)
    victim = eng.submit(_req(cfg, seed=8))
    eng.abort(victim.req_id)
    victim.result(timeout=30)
    again = eng.submit(_req(cfg, n_new=6, seed=7)).result(timeout=120)
    assert list(again.tokens) == list(ref.tokens)   # greedy, bit-identical


def test_abort_mm_leader_promotes_inflight_waiter(engine):
    """Two requests sharing one mm payload dedup onto a single in-flight
    encode; aborting the leader must not strand the follower."""
    cfg, eng = engine
    leader = eng.submit(_req(cfg, mm=True, seed=11))
    follower = eng.submit(_req(cfg, n_new=4, mm=True, seed=11))
    eng.abort(leader.req_id)
    out = follower.result(timeout=120)
    assert out.error is None and len(out.tokens) == 4
    leader.result(timeout=30)
    eng.collect(leader.req_id)


def test_request_timeout_is_timeout_error(engine):
    cfg, eng = engine
    handle = eng.submit(_req(cfg))
    with pytest.raises(RequestTimeout) as ei:
        handle.result(timeout=0.05)
    assert isinstance(ei.value, TimeoutError)
    assert ei.value.req_id == handle.req_id
    assert ei.value.waited == pytest.approx(0.05)
    # stream() raises the same distinct subclass
    with pytest.raises(RequestTimeout):
        for _ in handle.stream(timeout=0.01):
            pass
    eng.abort(handle.req_id)
    handle.result(timeout=30)


def test_abort_in_dense_mode(setup):
    cfg, params = setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=1, decode_batch=2, mode="dense"))
    eng.start()
    try:
        victim = eng.submit(_req(cfg))
        eng.abort(victim.req_id)
        out = victim.result(timeout=30)
        assert out.error is not None
        ok = eng.submit(_req(cfg, n_new=3, seed=3)).result(timeout=120)
        assert len(ok.tokens) == 3 and ok.error is None
    finally:
        eng.stop()
