"""Continuous-batching scheduler: chunked prefill correctness, FIFO
admission with pool-pressure backoff (no head-of-line busy-wait),
preempt-aware requeue, shutdown stranding, encode-stampede dedup, and
submit-time length validation in both modes.

The Scheduler itself is duck-typed over the P/D stages, so the policy
tests (FIFO, backoff, budget, requeue-front) drive it with thread-free
stubs; the math tests boot the real engine on a reduced model.
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model, dense
from repro.serving import (EPDEngine, EngineConfig, PrefillProgress,
                           RequestState, SamplingParams, Scheduler,
                           ServeRequest)
from repro.serving.stages import PagedKVState, PagedPrefillStage, ServeStats
from repro.serving.transfer import PsiEP, PsiPD, MMTokenCache


@pytest.fixture(scope="module")
def text_setup():
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _req(rid, n_prompt, max_new=4, cfg=None, seed=0, **kw):
    rng = np.random.default_rng(seed + rid)
    vocab = cfg.vocab if cfg else 512
    # stub-scheduler requests arrive prefill-ready (as from ψ_EP)
    kw.setdefault("state", RequestState.PREFILLING)
    return ServeRequest(req_id=rid,
                        prompt=rng.integers(0, vocab, n_prompt)
                        .astype(np.int32),
                        max_new_tokens=max_new, **kw)


# ================================================== scheduler policy (stubs)
class StubPrefill:
    """Pool of ``capacity`` abstract blocks, 1 block per 16 tokens."""
    chunk = 16

    def __init__(self, capacity=4):
        self.free = capacity
        self.held = {}
        self.chunk_calls = []          # req_id per run_chunk call

    def start(self, req, mm_tokens):
        need = -(-(len(req.prompt) + 1) // 16)
        if need > self.free:
            return None
        self.free -= need
        self.held[req.req_id] = need
        return PrefillProgress(req=req, mm_tokens=mm_tokens,
                               x=np.zeros((len(req.prompt), 1), np.float32))

    def run_chunk(self, task):
        self.chunk_calls.append(task.req.req_id)
        task.n_done = min(task.total, task.n_done + self.chunk)
        if task.done:
            task.first_tok = 1
            task.req.emit(1)
            return True
        return False

    def abandon(self, task):
        self.free += self.held.pop(task.req.req_id, 0)


class StubDecode:
    def __init__(self, prefill):
        self.prefill = prefill
        self.admitted = []             # req_ids in ψ_PD arrival order
        self.live = []

    @property
    def active_count(self):
        return len(self.live)

    def step(self, psi_pd):
        import queue as _q
        while True:
            try:
                t = psi_pd.recv_nowait()
            except _q.Empty:
                break
            self.admitted.append(t.req.req_id)
            self.live.append(t)
        done = [t for t in self.live
                if len(t.req.tokens) >= t.req.max_new_tokens]
        for t in done:
            self.live.remove(t)
            self.prefill.abandon(t)    # release stub blocks
        stepped = len(self.live)
        for t in self.live:
            t.req.emit(2)
        return stepped

    def abort_all(self, on_fail):
        for t in self.live:
            on_fail(t.req)
        self.live = []


def _stub_sched(capacity=4, budget=0, decode_batch=4):
    ecfg = EngineConfig(decode_batch=decode_batch, prefill_chunk=16,
                        step_token_budget=budget)
    pre = StubPrefill(capacity)
    dec = StubDecode(pre)
    stats = ServeStats()
    psi_ep, psi_pd = PsiEP(MMTokenCache(0)), PsiPD()
    failed = []
    sched = Scheduler(ecfg, pre, dec, psi_ep, psi_pd, stats,
                      threading.Event(),
                      on_fail=lambda r, e: failed.append((r.req_id, e)))
    sched.chunk = pre.chunk
    sched.budget = budget or (decode_batch + pre.chunk)
    return sched, pre, dec, psi_ep, stats, failed


def test_admission_is_fifo_with_pool_backoff():
    """A full pool holds the FIFO head in place (backoff) — later
    arrivals must not jump the queue and starve it."""
    sched, pre, dec, psi_ep, stats, _ = _stub_sched(capacity=3)
    big = _req(1, 40, max_new=1)     # 3 blocks: fills the pool alone
    small = _req(2, 8, max_new=1)    # would fit in the leftover... never
    third = _req(3, 8, max_new=1)
    for r in (big, small, third):
        psi_ep.send(r, None)
    for _ in range(50):
        sched.step()
        if len(dec.admitted) == 3:
            break
    # strict FIFO: big admitted first even though small fits sooner
    assert dec.admitted == [1, 2, 3]
    assert stats.data["admission_backoffs"] >= 1


def test_preempted_request_requeues_at_front():
    sched, pre, dec, psi_ep, stats, _ = _stub_sched(capacity=10)
    r1, r2 = _req(1, 8, max_new=1), _req(2, 8, max_new=1)
    psi_ep.send(r1, None)
    victim = _req(9, 8, max_new=1)
    victim.state = RequestState.PREFILLING
    sched.requeue(victim, None)      # preemption: front of the line
    psi_ep.send(r2, None)
    for _ in range(20):
        sched.step()
        if len(dec.admitted) == 3:
            break
    assert dec.admitted[0] == 9


def test_token_budget_caps_chunks_per_iteration():
    """With decode slots active, prefill chunks per iteration are limited
    to the leftover budget — decode is never starved by a long prompt."""
    sched, pre, dec, psi_ep, stats, _ = _stub_sched(
        capacity=64, budget=32, decode_batch=4)
    # keep decode busy so the stepped>0 path is exercised
    runner = _req(50, 8, max_new=30)
    psi_ep.send(runner, None)
    sched.step()                      # admits + completes runner's prefill
    sched.step()                      # decode now live
    assert dec.active_count == 1
    long_req = _req(51, 160, max_new=1)     # 10 chunks of 16
    psi_ep.send(long_req, None)
    calls_before = len(pre.chunk_calls)
    sched.step()
    calls = len(pre.chunk_calls) - calls_before
    # budget 32, decode spent 1 -> floor((32-1)/16) = 1 chunk this iter
    assert calls == 1
    # and the long prompt still completes across iterations
    for _ in range(30):
        sched.step()
        if 51 in dec.admitted:
            break
    assert 51 in dec.admitted


def test_idle_decode_still_guarantees_prefill_progress():
    """budget smaller than one chunk: a chunk must still run when decode
    is idle (guaranteed progress, no livelock)."""
    sched, pre, dec, psi_ep, stats, _ = _stub_sched(capacity=8, budget=4)
    psi_ep.send(_req(1, 60, max_new=1), None)    # 4 chunks
    n = 0
    while 1 not in dec.admitted and n < 50:
        sched.step()
        n += 1
    assert 1 in dec.admitted


def test_scheduler_drain_returns_stranded():
    sched, pre, dec, psi_ep, stats, _ = _stub_sched(capacity=3)
    a, b = _req(1, 40, max_new=1), _req(2, 40, max_new=1)
    psi_ep.send(a, None)
    psi_ep.send(b, None)
    sched.step()                      # admits a (pool now full), b queued
    stranded = sched.drain()
    assert {r.req_id for r in stranded} == {1, 2}
    assert pre.free == 3              # a's blocks released by abandon


# ===================================================== chunked prefill math
def test_chunked_prefill_logits_match_unchunked(text_setup):
    """Chunk-by-chunk prefill through pool blocks reproduces the one-shot
    prefill_core logits to bf16 rounding (the KV pool stores bf16; only
    reduction order differs)."""
    cfg, params = text_setup
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 100).astype(np.int32)
    ref_logits, _, _ = dense.prefill_core(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]})

    ecfg = EngineConfig(decode_batch=2, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=32)
    stats = ServeStats()
    kv = PagedKVState(model, cfg, ecfg)
    stage = PagedPrefillStage(model, cfg, params, ecfg, stats, kv)
    req = ServeRequest(req_id=1, prompt=prompt, max_new_tokens=4)
    captured = {}
    orig = stage._finish_prefill
    stage._finish_prefill = (
        lambda t, lg: captured.update(l=np.asarray(lg, np.float32))
        or orig(t, lg))
    task = stage.start(req, None)
    n_chunks = 0
    while not stage.run_chunk(task):
        n_chunks += 1
    assert n_chunks + 1 == 4                   # 100 tokens / 32-chunks
    np.testing.assert_allclose(captured["l"],
                               np.asarray(ref_logits, np.float32),
                               atol=0.05)      # few bf16 ULPs
    kv.mgr.free(1)
    assert kv.mgr.used_blocks == 0


def test_chunked_engine_is_deterministic_and_completes(text_setup):
    """Long prompts through the chunked scheduler: token output is
    run-to-run deterministic and the chunk counter advances."""
    cfg, params = text_setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 90).astype(np.int32)
    runs = []
    for _ in range(2):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=2, kv_blocks=64, max_seq_len=256,
            prefill_chunk=32))
        eng.start()
        try:
            eng.submit(ServeRequest(req_id=1, prompt=prompt.copy(),
                                    max_new_tokens=6))
            runs.append(eng.result(1, timeout=300).tokens)
        finally:
            eng.stop()
        assert eng.stats["prefill_chunks"] >= 3
        assert eng.kv_mgr.used_blocks == 0
    assert runs[0] == runs[1] and len(runs[0]) == 6


def test_chunked_replay_after_preemption_is_identical(text_setup):
    """A preempted long-prompt request replays through chunked prefill
    and re-emits the identical token prefix."""
    cfg, params = text_setup
    rng = np.random.default_rng(4)
    # 44-token prompts: prefill takes 3 blocks (45 tokens), the 49th
    # token's append needs a 4th — with 20 new tokens both requests are
    # mid-decode when the 7-block pool runs dry, forcing an OutOfBlocks
    # preemption whose victim replays through chunked prefill
    prompts = [rng.integers(0, cfg.vocab, 44).astype(np.int32)
               for _ in range(2)]
    outs = {}
    for name, blocks in (("ample", 64), ("tight", 7)):
        eng = EPDEngine(cfg, params, EngineConfig(
            decode_batch=2, kv_blocks=blocks, kv_block_size=16,
            max_seq_len=112, prefill_chunk=16))
        eng.start()
        try:
            for i, p in enumerate(prompts):
                eng.submit(ServeRequest(req_id=i + 1, prompt=p.copy(),
                                        max_new_tokens=20))
            outs[name] = [eng.result(i + 1, timeout=300).tokens
                          for i in range(2)]
        finally:
            eng.stop()
        if name == "tight":
            assert eng.stats["preemptions"] >= 1
        assert eng.kv_mgr.used_blocks == 0
    assert outs["ample"] == outs["tight"]


# ==================================================== shutdown stranding
def test_stop_fails_inflight_decode_and_queued_requests(text_setup):
    """Regression: stop() must fail requests parked anywhere in the
    pipeline (decoding, pool-pressure backoff queue) so result()/stream()
    return promptly instead of hanging to their timeout."""
    cfg, params = text_setup
    rng = np.random.default_rng(6)
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=3, kv_block_size=16, max_seq_len=48))
    eng.start()
    # r1 occupies the pool and decodes; r2 waits in the admission queue
    h1 = eng.submit(ServeRequest(
        req_id=1, prompt=rng.integers(0, cfg.vocab, 30).astype(np.int32),
        max_new_tokens=16))
    h2 = eng.submit(ServeRequest(
        req_id=2, prompt=rng.integers(0, cfg.vocab, 30).astype(np.int32),
        max_new_tokens=16))
    time.sleep(0.3)
    eng.stop()
    t0 = time.perf_counter()
    for h in (h1, h2):
        out = h.result(timeout=10)       # would TimeoutError pre-fix
        if out.state is RequestState.FAILED:
            assert "stopped" in out.error
        else:                            # finished before stop() landed
            assert out.state is RequestState.DONE
    assert time.perf_counter() - t0 < 5.0
    assert eng.kv_mgr.used_blocks == 0   # stranded blocks released
    assert eng._threads == []


def test_stop_fails_streaming_consumer(text_setup):
    cfg, params = text_setup
    rng = np.random.default_rng(8)
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=64, max_seq_len=256))
    eng.start()
    h = eng.submit(ServeRequest(
        req_id=1, prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
        max_new_tokens=200))
    it = h.stream(timeout=30)
    next(it)                             # at least one token flowing
    eng.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        for _ in it:
            pass

def test_stop_fails_dense_mode_residents(text_setup):
    cfg, params = text_setup
    rng = np.random.default_rng(9)
    eng = EPDEngine(cfg, params, EngineConfig(
        mode="dense", decode_batch=2, max_seq_len=256))
    eng.start()
    handles = [eng.submit(ServeRequest(
        req_id=i, prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
        max_new_tokens=120)) for i in (1, 2, 3)]
    time.sleep(0.2)
    eng.stop()
    for h in handles:
        out = h.result(timeout=10)
        assert out.state in (RequestState.FAILED, RequestState.DONE)


# ================================================== encode anti-stampede
def test_concurrent_identical_mm_submits_share_one_encode(vlm_setup):
    """Two byte-identical multimodal submissions in flight together must
    run ONE request's worth of IRP shards — the second waits for the
    first's merged tokens instead of stampeding the encoder."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(12)
    M = 2 * cfg.modality.tokens_per_item
    mm = (rng.standard_normal((M, cfg.modality.enc_d_model))
          .astype(np.float32) * 0.1)
    prompt = np.arange(M + 6, dtype=np.int32) % cfg.vocab

    def mk(rid):
        return ServeRequest(req_id=rid, prompt=prompt.copy(),
                            mm_embeds=mm.copy(),
                            mm_positions=np.arange(1, M + 1,
                                                   dtype=np.int32),
                            max_new_tokens=4)

    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=64, max_seq_len=128))
    # submit BOTH before starting the workers: deterministically
    # simultaneous — both miss the ψ_EP cache
    h1, h2 = eng.submit(mk(1)), eng.submit(mk(2))
    n_shards = len(eng.encode_stage.plan_shards(mk(99)))
    eng.start()
    try:
        o1, o2 = h1.result(timeout=300), h2.result(timeout=300)
    finally:
        eng.stop()
    assert eng.encode_stage.shards_run == n_shards   # one request's worth
    assert o1.tokens == o2.tokens
    assert o2.mm_cache_hit                           # joined in flight
    assert eng.stats["mm_inflight_hits"] == 1
    assert eng.stats["mm_cache_misses"] == 2         # both probed & missed


# ============================================== submit-time validation
def test_submit_length_validation_in_both_modes(text_setup):
    """Dense mode now rejects oversized prompts at submit (previously an
    opaque prefill failure); paged keeps the pool-capacity bound."""
    cfg, params = text_setup
    dense_eng = EPDEngine(cfg, params, EngineConfig(
        mode="dense", max_seq_len=32))
    with pytest.raises(ValueError, match="exceeds capacity"):
        dense_eng.submit(ServeRequest(req_id=1,
                                      prompt=np.zeros(30, np.int32),
                                      max_new_tokens=8))
    # boundary: prompt + max_new == cap is admissible (the dead
    # max(S+max_new, S+1) expression is gone; S+1 never binds)
    ok = ServeRequest(req_id=2, prompt=np.zeros(24, np.int32),
                      max_new_tokens=8)
    handle = dense_eng.submit(ok)
    assert handle.req_id == 2
    paged_eng = EPDEngine(cfg, params, EngineConfig(
        kv_blocks=2, kv_block_size=16, max_seq_len=64))
    with pytest.raises(ValueError, match="pool"):
        paged_eng.submit(ServeRequest(req_id=3,
                                      prompt=np.zeros(30, np.int32),
                                      max_new_tokens=8))
    # max_new_tokens=0 must be rejected: prefill always needs S+1 block
    # capacity, so a prompt exactly filling the pool would pass the
    # length check yet wedge the admission queue head forever
    with pytest.raises(ValueError, match="max_new_tokens"):
        paged_eng.submit(ServeRequest(req_id=4,
                                      prompt=np.zeros(8, np.int32),
                                      max_new_tokens=0))
