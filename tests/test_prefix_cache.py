"""Block-level KV prefix caching (EngineConfig.prefix_cache).

The contract mirrors the ψ_EP mm-token cache's: caching is a pure
optimization — greedy token streams are BIT-IDENTICAL with the cache on
vs off on every topology (packed runner, two_program oracle, cluster
with ψ_PD migration), while a repeated-prefix workload provably skips
prefill compute (fewer chunk rows; ZERO for a fully-cached prompt, whose
first token comes from the decode stage's pending-x row). Eviction is
LRU over unreferenced cached blocks only; divergence inside a shared
block copies-on-write.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ClusterEngine, EngineConfig, EPDEngine,
                           ServeRequest)


@pytest.fixture(scope="module")
def text_setup():
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lengths, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lengths]


def _repeat_mix(cfg, seed=11):
    """A chat-shaped workload: repeats and shared system-prompt prefixes."""
    base, other = _prompts(cfg, (80, 40), seed=seed)
    shared_tail = _prompts(cfg, (24,), seed=seed + 1)[0]
    return [base, other, base.copy(),                  # exact repeat
            np.concatenate([base[:48], shared_tail]),  # shared prefix
            base.copy()]


def _serve(cfg, params, prompts, max_new=6, engine_cls=EPDEngine,
           topo=None, **ecfg_kw):
    base = dict(decode_batch=2, kv_blocks=64, max_seq_len=256,
                prefill_chunk=32)
    base.update(ecfg_kw)
    ecfg = EngineConfig(**base)
    eng = (engine_cls(cfg, params, ecfg) if topo is None
           else engine_cls(cfg, params, ecfg, topo))
    eng.start()
    try:
        for i, p in enumerate(prompts):
            eng.submit(ServeRequest(req_id=i + 1, prompt=p.copy(),
                                    max_new_tokens=max_new))
        return [eng.result(i + 1, timeout=300).tokens
                for i in range(len(prompts))], eng
    finally:
        eng.stop()


# ============================================ greedy bit-identity on/off
@pytest.mark.parametrize("runner", ["packed", "two_program"])
def test_cache_on_off_bit_identity_single_engine(text_setup, runner):
    cfg, params = text_setup
    prompts = _repeat_mix(cfg)
    outs = {}
    for on in (False, True):
        out, eng = _serve(cfg, params, prompts, runner=runner,
                          prefix_cache=on)
        outs[on] = out
        if on:
            assert eng.stats["prefix_cache_hits"] >= 2
            assert eng.stats["prefix_tokens_reused"] > 0
        else:
            assert eng.stats["prefix_tokens_reused"] == 0
    assert outs[True] == outs[False]


def test_cache_on_off_bit_identity_cluster_migration(text_setup):
    """'2E1P1D': every prefill migrates P->D; cache-on must stay
    bit-identical AND reuse the prefix on repeats (matched on the P
    instance; the migrated keys re-pin / seed the D instance's index)."""
    cfg, params = text_setup
    prompts = _repeat_mix(cfg)
    outs = {}
    for on in (False, True):
        out, eng = _serve(cfg, params, prompts, engine_cls=ClusterEngine,
                          topo="2E1P1D", prefix_cache=on)
        outs[on] = out
        assert eng.stats["pd_migrations"] == len(prompts)
        if on:
            assert eng.stats["prefix_tokens_reused"] > 0
    assert outs[True] == outs[False]


# ======================================== fully-cached -> zero prefill rows
def test_fully_cached_prefix_runs_zero_prefill_rows(text_setup):
    cfg, params = text_setup
    (p,) = _prompts(cfg, (64,), seed=5)          # S % block_size == 0
    ecfg = EngineConfig(decode_batch=2, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=32, prefix_cache=True)
    eng = EPDEngine(cfg, params, ecfg)
    eng.start()
    try:
        eng.submit(ServeRequest(req_id=1, prompt=p.copy(), max_new_tokens=6))
        r1 = eng.result(1, timeout=300)
        s0 = dict(eng.stats)           # snapshot (the property is live)
        eng.submit(ServeRequest(req_id=2, prompt=p.copy(), max_new_tokens=6))
        r2 = eng.result(2, timeout=300)
        s1 = dict(eng.stats)
    finally:
        eng.stop()
    assert r2.tokens == r1.tokens
    # the repeat ran ZERO prefill rows: no chunk was planned or executed
    assert s1["packed_prefill_tokens"] == s0["packed_prefill_tokens"]
    assert s1["prefill_chunks"] == s0["prefill_chunks"]
    assert s1["prefill_completions"] == s0["prefill_completions"] + 1
    assert s1["prefix_tokens_reused"] - s0["prefix_tokens_reused"] == 64
    assert r2.ttft > 0       # pending-x row stamped the first token


# ============================================================ copy-on-write
def test_cow_on_concurrent_fully_cached_divergence(text_setup):
    """Two live requests sharing the final prompt block: each pending-x
    admission must write into a PRIVATE copy (refcount > 1 -> COW)."""
    cfg, params = text_setup
    (p,) = _prompts(cfg, (64,), seed=7)
    ecfg = EngineConfig(decode_batch=3, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=32, prefix_cache=True)
    eng = EPDEngine(cfg, params, ecfg)
    eng.start()
    try:
        eng.submit(ServeRequest(req_id=1, prompt=p.copy(),
                                max_new_tokens=10))
        warm = eng.result(1, timeout=300).tokens
        for rid in (2, 3):                       # concurrent repeats
            eng.submit(ServeRequest(req_id=rid, prompt=p.copy(),
                                    max_new_tokens=10))
        outs = [eng.result(rid, timeout=300).tokens for rid in (2, 3)]
        stats = eng.stats
    finally:
        eng.stop()
    assert outs[0] == warm and outs[1] == warm
    assert stats["cow_copies"] >= 1
    assert stats["prefix_tokens_reused"] >= 2 * 64


# ===================================================== follower dedup
def test_concurrent_identical_prefills_dedupe(text_setup):
    """The KV analogue of the mm-encode stampede fix: the follower backs
    off behind the leader's in-flight prefill, then admits entirely from
    the leader's committed blocks."""
    cfg, params = text_setup
    (p,) = _prompts(cfg, (64,), seed=13)
    ecfg = EngineConfig(decode_batch=2, kv_blocks=64, max_seq_len=256,
                        prefill_chunk=16, step_token_budget=128,
                        prefix_cache=True)
    eng = EPDEngine(cfg, params, ecfg)
    # queue BOTH before the scheduler runs: the leader's prefill is
    # guaranteed in flight when the follower reaches admission
    eng.submit(ServeRequest(req_id=1, prompt=p.copy(), max_new_tokens=5))
    eng.submit(ServeRequest(req_id=2, prompt=p.copy(), max_new_tokens=5))
    eng.start()
    try:
        outs = [eng.result(rid, timeout=300).tokens for rid in (1, 2)]
        stats = eng.stats
    finally:
        eng.stop()
    assert outs[0] == outs[1]
    assert stats["prefix_inflight_waits"] >= 1
    assert stats["prefix_cache_hits"] >= 1
    assert stats["prefix_tokens_reused"] >= 64


# ================================== eviction + preemption under pressure
def test_lru_eviction_and_preemption_replay_under_pressure(text_setup):
    """A tight pool forces LRU eviction of cached blocks and OutOfBlocks
    preemption; replays stay deterministic — tight output == ample output
    (both cache-on), and only UNREFERENCED blocks were ever evicted (the
    run completing at all proves live blocks survived)."""
    cfg, params = text_setup
    a, b, c = _prompts(cfg, (44, 44, 44), seed=4)
    # repeats exercise cached replay; the trailing cold prompt's decode
    # growth must EVICT the earlier prompts' unreferenced cached blocks
    prompts = [a, b, a.copy(), b.copy(), c]
    outs = {}
    for name, blocks in (("ample", 64), ("tight", 7)):
        out, eng = _serve(cfg, params, prompts, max_new=20,
                          kv_blocks=blocks, kv_block_size=16,
                          max_seq_len=112, prefill_chunk=16,
                          runner="packed", prefix_cache=True)
        outs[name] = out
        if name == "tight":
            assert eng.stats["preemptions"] >= 1
            assert eng.stats["prefix_evictions"] >= 1
        assert eng.kv_mgr.used_blocks == 0
        assert eng.kv_mgr.free_blocks == blocks
    assert outs["ample"] == outs["tight"]
