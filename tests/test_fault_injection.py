"""Fault injection, dead-instance failover, and elastic scaling.

Three layers of coverage for the degraded-cluster path:

* pure units — ``FaultPlan`` window algebra, the latency-aware assigner's
  straggler shedding, ``LoadEstimator`` scale hints;
* simulator — instance deaths (KV reachable and not), stalls and
  slowdowns injected into the discrete-event loop: every request still
  finishes, nothing strands, and the fault counters tell the story;
* real ``ClusterEngine`` — a mid-decode death re-homes residents
  byte-exact (greedy streams stay bit-identical to an undisturbed run),
  elastic add/remove strands nothing, and the simulator agrees with the
  real engine on the structural fault metrics under the same plan.
"""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100_80G, SLO, Death, FaultPlan, Slowdown, Stall
from repro.core.cluster import ClusterSpec, build_cluster
from repro.core.request import Request
from repro.core.scheduler import LATENCY_AWARE, Assigner
from repro.core.simulator import Simulator

TEXT_CFG = get_config("internlm2-20b")          # no modality: P/D suffice


# --------------------------------------------------------------- units
def test_fault_plan_windows():
    plan = FaultPlan(
        slowdowns=[Slowdown(iid=0, start=1.0, factor=2.0, duration=2.0),
                   Slowdown(iid=0, start=2.0, factor=3.0, duration=2.0)],
        stalls=[Stall(iid=1, start=1.0, duration=0.5)],
        deaths=[Death(iid=2, at=5.0, kv_reachable=False)])
    assert plan.multiplier(0, 0.5) == 1.0
    assert plan.multiplier(0, 1.5) == 2.0
    assert plan.multiplier(0, 2.5) == 6.0       # overlapping: product
    assert plan.multiplier(0, 3.5) == 3.0
    assert plan.multiplier(1, 2.5) == 1.0       # other instance untouched
    assert plan.stall_until(1, 1.2) == 1.5
    assert plan.stall_until(1, 2.0) == 2.0      # no active stall: now
    assert plan.death_for(2).kv_reachable is False
    assert plan.death_for(0) is None
    assert not plan.dead(2, 4.9) and plan.dead(2, 5.1)
    assert plan.horizon == 5.0
    assert FaultPlan().horizon == 0.0


def test_latency_aware_assigner_sheds_straggler():
    """A limping instance (8x the peer's service EWMA) receives a small
    minority of picks instead of its round-robin half."""
    class Stub:
        def __init__(self, lat_ms):
            self.accepting = True
            self._lat = lat_ms
            self.n = 0

        def load(self):
            return float(self.n)

        def latency_ms(self):
            return self._lat

    fast, slow = Stub(10.0), Stub(80.0)
    a = Assigner(LATENCY_AWARE)
    for _ in range(27):
        picked = [fast, slow][a.pick([fast, slow])]
        picked.n += 1
    assert fast.n > 2 * slow.n, (fast.n, slow.n)
    # with no latency signal yet it degrades to least-loaded (no crash)
    cold = [Stub(0.0), Stub(0.0)]
    cold[1].n = 5
    assert Assigner(LATENCY_AWARE).pick(cold) == 0


def test_load_estimator_scale_hints():
    from repro.core.load_estimator import LoadEstimator
    est = LoadEstimator(TEXT_CFG, A100_80G)
    # a hot decode-heavy arrival stream: 50 req/s of 400-token outputs
    for i in range(50):
        est.observe_raw(i * 0.02, n_patches=0, prefill_tokens=128,
                        output_len=400)
    util = est.utilization({"E": 0, "P": 1, "D": 1})
    assert util["E"] == 0.0                     # no mm demand at all
    assert util["D"] > util["P"]
    assert est.suggest_scale({"P": 1, "D": 1}) == ("up", "D")
    # demand against a zero-instance stage flags as inf
    assert est.utilization({"P": 0, "D": 1})["P"] == float("inf")
    # a nearly idle stream with a wide fleet suggests shrinking it
    idle = LoadEstimator(TEXT_CFG, A100_80G)
    for i in range(10):
        idle.observe_raw(i * 60.0, n_patches=0, prefill_tokens=16,
                         output_len=2)
    hint = idle.suggest_scale({"P": 2, "D": 4})
    assert hint is not None and hint[0] == "down"
    # ...but never below one instance of a served letter
    assert idle.suggest_scale({"P": 1, "D": 1}) is None


# ----------------------------------------------------------- simulator
def _sim_reqs(n=8, out_len=200, rate=50.0):
    return [Request(req_id=i, arrival=i / rate, prompt_len=64, n_items=0,
                    patches_per_item=0, tokens_per_patch=0,
                    output_len=out_len, slo=SLO(5.0, 0.5))
            for i in range(n)]


def _run_sim(faults=None, policy="round_robin", spec="1P2D", **req_kw):
    cspec = ClusterSpec(spec, irp=False, assign_policy=policy)
    sim = Simulator(TEXT_CFG, A100_80G, build_cluster(cspec, TEXT_CFG,
                                                      A100_80G),
                    assign_policy=policy, irp=False, faults=faults)
    out = sim.run(_sim_reqs(**req_kw))
    return sim, out


def _mid_decode_time():
    """A timestamp at which the whole batch is resident in decode (after
    every ψ_PD handoff, before the first completion) — found from a dry
    run; the simulator is deterministic, so it transfers to fault runs."""
    _, out = _run_sim()
    t_lo = max(r.pd_transfer_end for r in out)
    t_hi = min(r.finish for r in out)
    assert t_lo < t_hi, "workload finishes before all residents decode"
    return (t_lo + t_hi) / 2.0


def test_sim_death_kv_reachable_migrates_residents():
    t = _mid_decode_time()
    plan = FaultPlan(deaths=[Death(iid=1, at=t)])     # first D of "1P2D"
    sim, out = _run_sim(faults=plan)
    assert all(r.done() for r in out)
    assert sim.fault_stats["instance_deaths"] == 1
    assert sim.fault_stats["fault_failovers"] >= 1    # residents moved
    assert sim.fault_stats["fault_replays"] == 0      # KV was reachable
    assert sim.fault_stats["stranded"] == 0
    # survivors absorbed the work: the run still produces sane timelines
    for r in out:
        assert r.arrival <= r.prefill_end <= r.finish


def test_sim_death_kv_unreachable_replays_from_prompt():
    t = _mid_decode_time()
    plan = FaultPlan(deaths=[Death(iid=1, at=t, kv_reachable=False)])
    sim, out = _run_sim(faults=plan)
    assert all(r.done() for r in out)
    assert sim.fault_stats["instance_deaths"] == 1
    assert sim.fault_stats["fault_replays"] >= 1      # back through P
    assert sim.fault_stats["fault_failovers"] == 0
    assert sim.fault_stats["stranded"] == 0


def test_sim_death_with_no_surviving_stage_strands_not_hangs():
    """Killing the ONLY decode instance leaves its residents nowhere to
    go — they strand (counted) instead of wedging the event loop."""
    _, dry = _run_sim(spec="2P1D")
    t_lo = max(r.pd_transfer_end for r in dry)
    t_hi = min(r.finish for r in dry)
    plan = FaultPlan(deaths=[Death(iid=2, at=(t_lo + t_hi) / 2)])
    sim, out = _run_sim(faults=plan, spec="2P1D")     # terminates
    assert sim.fault_stats["instance_deaths"] == 1
    assert sim.fault_stats["stranded"] >= 1


def test_sim_stall_delays_but_finishes():
    base_sim, base = _run_sim()
    t = _mid_decode_time()
    plan = FaultPlan(stalls=[Stall(iid=1, start=t, duration=2.0)])
    sim, out = _run_sim(faults=plan)
    assert all(r.done() for r in out)
    assert sim.fault_stats["stranded"] == 0
    assert max(r.finish for r in out) > max(r.finish for r in base)


def test_sim_slowdown_straggler_shed_with_latency_aware_routing():
    """A 6x-slow D instance under round-robin drags mean latency; the
    latency-aware policy sheds load off the straggler and recovers a
    solid chunk of it."""
    slow = FaultPlan(slowdowns=[Slowdown(iid=1, start=0.0, factor=6.0)])
    _, rr = _run_sim(faults=slow, policy="round_robin")
    _, la = _run_sim(faults=slow, policy="latency_aware")
    assert all(r.done() for r in rr) and all(r.done() for r in la)
    lat = lambda out: sum(r.e2e_latency for r in out) / len(out)  # noqa: E731
    assert lat(la) < lat(rr), (lat(la), lat(rr))


# -------------------------------------------------------- real cluster
@pytest.fixture(scope="module")
def text_setup():
    import jax
    from repro.models import build_model
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(1))
    return cfg, params


def _wait(pred, timeout=60.0, dt=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(dt)
    return False


def _text_reqs(cfg, prompts, max_new, base=0):
    from repro.serving import ServeRequest
    return [ServeRequest(req_id=base + i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _reference_tokens(cfg, params, ec, cc, prompts, max_new):
    from repro.serving import ClusterEngine
    clu = ClusterEngine(cfg, params, ec, cc)
    clu.start()
    try:
        reqs = _text_reqs(cfg, prompts, max_new)
        for r in reqs:
            clu.submit(r)
        return [list(clu.result(r.req_id, timeout=300).tokens)
                for r in reqs]
    finally:
        clu.stop()


@pytest.mark.cluster
@pytest.mark.slow
@pytest.mark.parametrize("kv_reachable", [True, False],
                         ids=["kv-migrate", "kv-lost-replay"])
def test_mid_decode_death_bit_parity(text_setup, kv_reachable):
    """Kill a decode instance while its residents are mid-stream. With
    the KV reachable they migrate byte-exact (ψ_PD extract/inject); with
    it lost they replay from the prompt. Either way every request
    finishes with tokens bit-identical to an undisturbed run, and
    nothing strands."""
    from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                               RequestState)
    cfg, params = text_setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, 15).astype(np.int32)
               for _ in range(4)]
    max_new = 16
    ec = EngineConfig(n_encode_workers=1, max_new_tokens=max_new,
                      decode_batch=2, kv_blocks=32, kv_block_size=16,
                      max_seq_len=128)
    # monitor_interval is huge so the test drives supervise_once itself
    cc = ClusterConfig(spec="1P2D", monitor_interval=60.0)
    ref = _reference_tokens(cfg, params, ec, cc, prompts, max_new)

    clu = ClusterEngine(cfg, params, ec, cc)
    clu.start()
    try:
        reqs = _text_reqs(cfg, prompts, max_new)
        for r in reqs:
            clu.submit(r)
        # steady state: every request handed off to a decode pool (some
        # may still be token-less — exactly the victims the byte-exact
        # path must keep bit-identical), none finished yet
        assert _wait(lambda: clu.stats["pd_migrations"] >= len(reqs),
                     timeout=120)
        assert not any(r.finished for r in reqs)
        victim = clu.instances[1]               # first D of "1P2D"
        assert victim.role == "D"
        clu.set_fault_plan(FaultPlan(deaths=[
            Death(iid=1, at=0.0, kv_reachable=kv_reachable)]))
        assert _wait(lambda: not victim.alive), "executor ignored death"
        clu.supervise_once()                    # failover sweep
        outs = [clu.result(r.req_id, timeout=300) for r in reqs]
    finally:
        clu.stop()
    assert all(o.state is RequestState.DONE for o in outs)
    for r, expect in zip(reqs, ref):
        assert list(r.tokens) == expect, f"req {r.req_id} diverged"
    assert clu.stats["instance_deaths"] == 1
    if kv_reachable:
        assert clu.stats["fault_failovers"] >= 1
        assert clu.stats["fault_replays"] == 0
    else:
        assert clu.stats["fault_replays"] >= 1
        assert clu.stats["fault_failovers"] == 0
    states = clu.instance_states()
    assert states["dead"] == 1 and states["alive"] == 2
    for inst in clu.instances:
        if inst.alive and inst.kv is not None:
            assert inst.kv.mgr.used_blocks == 0


@pytest.mark.cluster
@pytest.mark.slow
def test_elastic_add_remove_zero_stranded(text_setup):
    """Scale up mid-traffic, then retire the ORIGINAL decode instance
    while it still holds residents: they migrate to the newcomer, the
    supervisor reaps the drained instance, and every request completes
    full-length."""
    from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                               RequestState)
    cfg, params = text_setup
    rng = np.random.default_rng(9)
    ec = EngineConfig(n_encode_workers=1, max_new_tokens=8, decode_batch=2,
                      kv_blocks=32, kv_block_size=16, max_seq_len=128)
    clu = ClusterEngine(cfg, params, ec,
                        ClusterConfig(spec="1P1D", monitor_interval=60.0))
    clu.start()
    try:
        reqs = _text_reqs(
            cfg, [rng.integers(0, cfg.vocab, 12).astype(np.int32)
                  for _ in range(6)], max_new=8)
        for r in reqs[:3]:
            clu.submit(r)
        added = clu.add_instance("D")
        assert added.iid == 2 and len(clu.instances) == 3
        for r in reqs[3:]:
            clu.submit(r)
        # the only P and the (not-yet-started) last D of a letter refuse
        assert clu.remove_instance(0) is False
        assert _wait(lambda: added.thread is not None
                     and added.thread.is_alive())
        assert clu.remove_instance(1) is True   # original D drains out
        assert clu.remove_instance(1) is False  # already retiring
        assert _wait(lambda: (clu.supervise_once() or
                              len(clu.instances) == 2), timeout=120)
        outs = [clu.result(r.req_id, timeout=300) for r in reqs]
    finally:
        clu.stop()
    assert all(o.state is RequestState.DONE for o in outs)
    assert all(len(o.tokens) == 8 for o in outs)
    assert clu.stats["scale_ups"] == 1
    assert clu.stats["scale_downs"] == 1
    assert [i.iid for i in clu.instances] == [0, 2]
    assert clu.scale_log and [e[1] for e in clu.scale_log] == ["up", "down"]
    for inst in clu.instances:
        if inst.kv is not None:
            assert inst.kv.mgr.used_blocks == 0


@pytest.mark.cluster
@pytest.mark.slow
def test_sim_vs_real_structural_agreement_under_faults(text_setup):
    """The same fault class — kill the first D of a "1P2D" topology with
    every request resident mid-decode, KV reachable — produces the same
    STRUCTURE in the simulator and the real engine: one death, residents
    re-homed by migration (not replay), zero stranded, all complete."""
    from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                               RequestState)
    cfg, params = text_setup
    # --- simulator side (cost-model config of the same shape class)
    t = _mid_decode_time()
    sim, sim_out = _run_sim(faults=FaultPlan(deaths=[Death(iid=1, at=t)]))
    # --- real side
    rng = np.random.default_rng(11)
    ec = EngineConfig(n_encode_workers=1, max_new_tokens=12, decode_batch=2,
                      kv_blocks=32, kv_block_size=16, max_seq_len=128)
    clu = ClusterEngine(cfg, params, ec,
                        ClusterConfig(spec="1P2D", monitor_interval=60.0))
    clu.start()
    try:
        reqs = _text_reqs(
            cfg, [rng.integers(0, cfg.vocab, 15).astype(np.int32)
                  for _ in range(4)], max_new=12)
        for r in reqs:
            clu.submit(r)
        assert _wait(lambda: clu.stats["pd_migrations"] >= len(reqs),
                     timeout=120)
        clu.set_fault_plan(FaultPlan(deaths=[Death(iid=1, at=0.0)]))
        assert _wait(lambda: not clu.instances[1].alive)
        clu.supervise_once()
        outs = [clu.result(r.req_id, timeout=300) for r in reqs]
    finally:
        clu.stop()
    # structural agreement, not wall-clock agreement
    assert clu.stats["instance_deaths"] == sim.fault_stats[
        "instance_deaths"] == 1
    assert clu.stats["fault_failovers"] >= 1
    assert sim.fault_stats["fault_failovers"] >= 1
    assert clu.stats["fault_replays"] == sim.fault_stats[
        "fault_replays"] == 0
    assert sim.fault_stats["stranded"] == 0
    assert all(r.done() for r in sim_out)
    assert all(o.state is RequestState.DONE for o in outs)
