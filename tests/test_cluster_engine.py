"""ClusterEngine: paper-notation topologies over the real engine.

Parity notes (see PR 3 / test_stop_tokens): GREEDY decode is asserted
bit-identical — the ``"1EPD"`` cluster drives the same Scheduler + stage
code as ``EPDEngine`` over one shared pool, and the cross-instance ψ_PD
migration is a byte-exact pool copy, so disaggregated topologies emit
the same greedy streams too. Nucleus (temperature>0) sampling is
EXCLUDED from cross-engine parity: it is ULP-sensitive near the top-p
boundary across kernel paths; seeded sampling remains deterministic
against its own topology.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ClusterConfig, ClusterEngine, EPDEngine,
                           EngineConfig, ServeRequest)

pytestmark = pytest.mark.cluster


@pytest.fixture(scope="module")
def vlm_setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _ecfg(**kw):
    base = dict(n_encode_workers=2, max_new_tokens=8, decode_batch=2)
    base.update(kw)
    return EngineConfig(**base)


def _requests(cfg, base_id):
    """3 multimodal (distinct payloads) + 2 text-only requests."""
    rng = np.random.default_rng(42)
    M = 2 * cfg.modality.tokens_per_item
    reqs = []
    for i in range(5):
        mm = i < 3
        reqs.append(ServeRequest(
            req_id=base_id + i,
            prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
            mm_embeds=(rng.standard_normal((M, cfg.modality.enc_d_model))
                       .astype(np.float32) * 0.1) if mm else None,
            mm_positions=(np.arange(1, M + 1, dtype=np.int32)
                          if mm else None),
            max_new_tokens=8))
    return reqs


def _serve(engine, reqs):
    engine.start()
    try:
        for r in reqs:
            engine.submit(r)
        return {r.req_id - reqs[0].req_id: list(
            engine.result(r.req_id, timeout=300).tokens) for r in reqs}
    finally:
        engine.stop()


@pytest.fixture(scope="module")
def ref_tokens(vlm_setup):
    """Greedy token streams from the single-pipeline EPDEngine."""
    cfg, params = vlm_setup
    return _serve(EPDEngine(cfg, params, _ecfg()), _requests(cfg, 0))


def test_1epd_greedy_parity_bit_identical(vlm_setup, ref_tokens):
    """Acceptance: ClusterEngine("1EPD") == EPDEngine, token for token."""
    cfg, params = vlm_setup
    clu = ClusterEngine(cfg, params, _ecfg(), "1EPD")
    got = _serve(clu, _requests(cfg, 100))
    assert got == ref_tokens
    assert clu.stats["pd_migrations"] == 0      # P and D share the pool


def test_disaggregated_parity_and_migrations(vlm_setup, ref_tokens):
    """"2E1P1D" (true EPD): every prefill migrates its KV to the decode
    instance, byte-exact — greedy streams stay bit-identical."""
    cfg, params = vlm_setup
    clu = ClusterEngine(cfg, params, _ecfg(), "2E1P1D")
    got = _serve(clu, _requests(cfg, 200))
    assert got == ref_tokens
    assert clu.stats["pd_migrations"] == 5      # one per request
    assert clu.stats["encode_shards"] == 6      # 3 mm requests x IRP 2
    # every pool is empty after the run
    for inst in clu.instances:
        if inst.kv is not None:
            assert inst.kv.mgr.used_blocks == 0


def test_distserve_baseline_topology(vlm_setup, ref_tokens):
    """"2EP1D" (DistServe shape): aggregated encode+prefill instances,
    disaggregated decode — same greedy streams."""
    cfg, params = vlm_setup
    clu = ClusterEngine(
        cfg, params, _ecfg(),
        ClusterConfig(spec="2EP1D", assign_policy="round_robin"))
    got = _serve(clu, _requests(cfg, 300))
    assert got == ref_tokens
    assert clu.stats["pd_migrations"] == 5


def test_vllm_baseline_topology(vlm_setup, ref_tokens):
    """"2EPD" (vLLM shape): fully aggregated instances, zero migrations."""
    cfg, params = vlm_setup
    clu = ClusterEngine(cfg, params, _ecfg(), "2EPD")
    got = _serve(clu, _requests(cfg, 400))
    assert got == ref_tokens
    assert clu.stats["pd_migrations"] == 0


def test_mm_cache_and_streaming(vlm_setup):
    """Cluster-level ψ_EP cache: a repeated payload skips E entirely;
    stream() works through the shared EngineBase machinery."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(5)
    M = 2 * cfg.modality.tokens_per_item
    mm = rng.standard_normal((M, cfg.modality.enc_d_model)).astype(
        np.float32) * 0.1
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    mk = lambda rid: ServeRequest(
        req_id=rid, prompt=prompt.copy(), mm_embeds=mm.copy(),
        mm_positions=np.arange(1, M + 1, dtype=np.int32),
        max_new_tokens=4)
    clu = ClusterEngine(cfg, params, _ecfg(), "2E1P1D")
    clu.start()
    try:
        h1 = clu.submit(mk(1))
        first = list(h1.stream(timeout=300))
        h1.result(timeout=300)
        h2 = clu.submit(mk(2))
        out2 = h2.result(timeout=300)
    finally:
        clu.stop()
    assert out2.mm_cache_hit
    assert clu.stats["mm_cache_hits"] == 1
    # identical payload + greedy decode: identical stream, zero new shards
    assert len(first) == 4 and list(out2.tokens) == first


def test_spec_and_config_validation(vlm_setup):
    cfg, params = vlm_setup
    with pytest.raises(ValueError):              # no D coverage
        ClusterEngine(cfg, params, _ecfg(), "2E1P")
    with pytest.raises(ValueError):              # unparseable spec
        ClusterEngine(cfg, params, _ecfg(), "xyz")
    with pytest.raises(ValueError):              # unknown routing policy
        ClusterEngine(cfg, params, _ecfg(),
                      ClusterConfig(spec="1EPD", assign_policy="bogus"))
    with pytest.raises(ValueError):              # dense mode stays EPDEngine
        ClusterEngine(cfg, params, _ecfg(mode="dense"), "1EPD")


def test_mm_request_requires_e_coverage(vlm_setup):
    """A "1P1D" cluster serves text; a modality payload is rejected at
    submit (clear error instead of a silent text-only prefill)."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(9)
    M = cfg.modality.tokens_per_item
    clu = ClusterEngine(cfg, params, _ecfg(), "1P1D")
    with pytest.raises(ValueError, match="no E-capable"):
        clu.submit(ServeRequest(
            req_id=1, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
            mm_embeds=rng.standard_normal(
                (M, cfg.modality.enc_d_model)).astype(np.float32),
            mm_positions=np.arange(1, M + 1, dtype=np.int32),
            max_new_tokens=2))


def test_encode_routing_failure_releases_dedup_waiters(vlm_setup):
    """Regression: when encode dispatch fails, the waiters merged onto
    the leader's in-flight ψ_EP key must fail with it. An arity bug
    passed the key as the leader argument, so ``_fail_inflight`` popped
    nothing and dedup waiters stranded until their result() timeout."""
    cfg, params = vlm_setup
    rng = np.random.default_rng(31)
    M = 2 * cfg.modality.tokens_per_item
    mm = rng.standard_normal((M, cfg.modality.enc_d_model)).astype(
        np.float32) * 0.1
    mk = lambda rid: ServeRequest(
        req_id=rid, prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
        mm_embeds=mm.copy(),
        mm_positions=np.arange(1, M + 1, dtype=np.int32),
        max_new_tokens=4)
    clu = ClusterEngine(cfg, params, _ecfg(), "2E1P1D")
    # engine NOT started: submits only enqueue, so the leader's encode
    # stays in-flight while the identical-payload waiter merges onto it
    h_lead, h_wait = clu.submit(mk(901)), clu.submit(mk(902))
    assert clu.stats["mm_inflight_hits"] == 1
    key = clu._mm_leading[901]

    def boom(job):
        raise RuntimeError("router down")

    clu._route_encode_job = boom
    clu._dispatch_encode(h_lead.req, key)       # re-dispatch fails
    assert h_lead.req.finished and h_wait.req.finished
    assert "encode routing failed" in (h_wait.req.error or "")
    assert key not in clu._mm_inflight and 901 not in clu._mm_leading
