"""HTTP gateway: OpenAI-shaped serving over a live engine.

Acceptance criteria from the serving-gateway PR:
  * greedy completions through the gateway are token-identical to a
    direct ``submit``/``result`` on the same engine — for both the
    single-pipeline ``EPDEngine`` and a ``"2E1P1D"`` ``ClusterEngine``;
  * SSE streaming yields the same tokens incrementally (concatenated
    deltas == non-streaming content);
  * exact HTTP status mapping (400/404/405/408/429/500) for the schema
    errors ``api.parse_chat_request`` raises;
  * a mid-stream client disconnect aborts server-side — the pool's
    free-block count returns to baseline — without stalling other
    streams.
"""
import http.client
import json
import threading
import time

import jax
import pytest

from fake_engine import FakeEngine, finish
from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ClusterConfig, ClusterEngine, EPDEngine,
                           EngineConfig, GatewayServer)
from repro.serving.api import parse_chat_request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("pixtral-12b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gateway(setup):
    cfg, params = setup
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, decode_batch=2, kv_blocks=64))
    eng.start()
    gw = GatewayServer(eng, request_timeout=120.0).start()
    yield cfg, eng, gw
    gw.stop()
    eng.stop()


PAYLOAD = {"messages": [{"role": "user", "content": "hello epd gateway"}],
           "max_tokens": 6, "temperature": 0.0}


def _post(gw, payload, stream=False, timeout=120):
    c = http.client.HTTPConnection(gw.host, gw.port, timeout=timeout)
    c.request("POST", "/v1/chat/completions",
              body=payload if isinstance(payload, (bytes, str))
              else json.dumps(payload),
              headers={"Content-Type": "application/json"})
    r = c.getresponse()
    if stream:
        return r.status, r, c
    body = r.read()
    c.close()
    return r.status, json.loads(body)


def _get(gw, path):
    c = http.client.HTTPConnection(gw.host, gw.port, timeout=30)
    c.request("GET", path)
    r = c.getresponse()
    body = r.read()
    c.close()
    return r.status, json.loads(body)


def _sse_events(raw: bytes):
    out = []
    for ev in raw.split(b"\n\n"):
        if not ev:
            continue
        assert ev.startswith(b"data: "), ev
        out.append(ev[6:].decode())
    return out


def _direct_tokens(cfg, eng, payload):
    out = eng.submit(parse_chat_request(cfg, payload)).result(timeout=120)
    assert out.error is None
    return list(out.tokens)


def test_unary_parity_with_direct_submit(gateway):
    cfg, eng, gw = gateway
    direct = _direct_tokens(cfg, eng, PAYLOAD)
    st, resp = _post(gw, PAYLOAD)
    assert st == 200
    choice = resp["choices"][0]
    assert choice["token_ids"] == direct          # token-identical
    assert choice["message"]["content"] == " ".join(str(t) for t in direct)
    assert choice["finish_reason"] == "length"
    assert resp["usage"]["completion_tokens"] == len(direct)
    assert resp["usage"]["total_tokens"] == (resp["usage"]["prompt_tokens"]
                                             + len(direct))
    t = resp["timings"]
    assert t["ttft"] > 0 and "tpot" in t and "mm_cache_hit" in t


def test_sse_stream_yields_same_tokens_incrementally(gateway):
    cfg, eng, gw = gateway
    direct = _direct_tokens(cfg, eng, PAYLOAD)
    st, r, c = _post(gw, dict(PAYLOAD, stream=True), stream=True)
    assert st == 200
    assert r.getheader("Content-Type") == "text/event-stream"
    events = _sse_events(r.read())
    c.close()
    assert events[-1] == "[DONE]"
    chunks = [json.loads(e) for e in events[:-1]]
    assert chunks[0]["choices"][0]["delta"]["role"] == "assistant"
    assert chunks[0]["object"] == "chat.completion.chunk"
    deltas = [ch["choices"][0]["delta"]["content"] for ch in chunks
              if "content" in ch["choices"][0]["delta"]]
    assert len(deltas) == len(direct)             # one event per token
    assert "".join(deltas) == " ".join(str(t) for t in direct)
    assert chunks[-1]["choices"][0]["finish_reason"] == "length"


@pytest.mark.parametrize("payload,needle", [
    ({"messages": []}, "missing messages"),
    ({"messages": [{"role": "u", "content": "x"}], "temperature": 9.0},
     "temperature out of range"),
    ({"messages": [{"role": "u", "content": "x"}], "top_p": 0.0},
     "top_p out of range"),
    ({"messages": [{"role": "u", "content": "x"}], "max_tokens": 0},
     "max_tokens out of range"),
    ({"messages": [{"role": "u", "content": [{"type": "bogus"}]}]},
     "unknown content type"),
])
def test_schema_errors_map_to_400(gateway, payload, needle):
    _, _, gw = gateway
    st, resp = _post(gw, payload)
    assert st == 400
    assert needle in resp["error"]["message"]


def test_oversized_prompt_maps_to_400(gateway):
    cfg, _, gw = gateway
    words = " ".join("w%d" % i for i in range(cfg.max_context + 1))
    st, resp = _post(gw, {"messages": [{"role": "u", "content": words}]})
    assert st == 400
    assert "OOCL" in resp["error"]["message"]


def test_malformed_json_maps_to_400(gateway):
    _, _, gw = gateway
    st, resp = _post(gw, b"{not json")
    assert st == 400 and "bad JSON" in resp["error"]["message"]
    st, resp = _post(gw, b'["a", "list"]')
    assert st == 400


def test_unknown_path_404_and_bad_method_405(gateway):
    _, _, gw = gateway
    st, _ = _get(gw, "/v1/bogus")
    assert st == 404
    st, _ = _get(gw, "/v1/chat/completions")
    assert st == 405


def test_health_and_metrics_endpoints(gateway):
    _, eng, gw = gateway
    st, h = _get(gw, "/health")
    assert st == 200 and h["ok"] is True
    st, m = _get(gw, "/metrics")
    assert st == 200
    assert m["gateway"]["completions"] >= 1
    assert m["admission"]["max_concurrent"] == gw.max_concurrent
    # engine counters ride along: packed-runner and prefix-cache stats
    for key in ("decode_steps", "packed_steps", "prefix_cache_hits",
                "aborts"):
        assert key in m["engine"], key


def test_timeout_maps_to_408_and_aborts():
    fake = FakeEngine(auto_complete=False)
    gw = GatewayServer(fake, request_timeout=0.2).start()
    try:
        st, resp = _post(gw, PAYLOAD)
        assert st == 408
        assert "timed out" in resp["error"]["message"]
        assert fake.aborted and gw.counters["timeouts_408"] == 1
        deadline = time.time() + 5
        while not fake.collected and time.time() < deadline:
            time.sleep(0.01)
        assert fake.collected        # gateway collected the dead request
    finally:
        gw.stop()


def test_overload_sheds_with_429():
    fake = FakeEngine(auto_complete=False)
    gw = GatewayServer(fake, max_concurrent=1, max_queue=0,
                       request_timeout=30.0).start()
    try:
        results = {}
        first = threading.Thread(
            target=lambda: results.update(first=_post(gw, PAYLOAD)))
        first.start()
        deadline = time.time() + 5
        while not fake.handles and time.time() < deadline:
            time.sleep(0.01)          # first request admitted + submitted
        assert fake.handles
        st, resp = _post(gw, PAYLOAD)
        assert st == 429
        assert "admission queue full" in resp["error"]["message"]
        assert gw.counters["rejected_429"] == 1
        finish(next(iter(fake.handles.values())).req, (1, 2))
        first.join(timeout=10)
        assert results["first"][0] == 200
    finally:
        gw.stop()


def test_engine_failure_maps_to_500():
    fake = FakeEngine(auto_complete=False)
    gw = GatewayServer(fake, request_timeout=30.0).start()
    try:
        def fail_soon():
            deadline = time.time() + 5
            while not fake.handles and time.time() < deadline:
                time.sleep(0.01)
            next(iter(fake.handles.values())).req.mark_failed("boom")
        t = threading.Thread(target=fail_soon)
        t.start()
        st, resp = _post(gw, PAYLOAD)
        t.join()
        assert st == 500 and "boom" in resp["error"]["message"]
    finally:
        gw.stop()


def test_disconnect_mid_stream_frees_blocks_without_stalling_others(gateway):
    cfg, eng, gw = gateway
    deadline = time.time() + 30
    while eng.kv_block_counts()[0] != eng.kv_block_counts()[1]:
        assert time.time() < deadline, "engine did not quiesce"
        time.sleep(0.05)
    free0 = eng.kv_block_counts()[0]
    long_payload = {"messages": [{"role": "user", "content": "victim req"}],
                    "max_tokens": 100, "stream": True}
    survivor_payload = dict(PAYLOAD, stream=True,
                            messages=[{"role": "user",
                                       "content": "survivor req"}])
    direct = _direct_tokens(cfg, eng, dict(survivor_payload, stream=False))

    st_v, rv, cv = _post(gw, long_payload, stream=True)
    st_s, rs, cs = _post(gw, survivor_payload, stream=True)
    assert st_v == 200 and st_s == 200
    # read a few victim events to ensure it is decoding, then hang up
    got = b""
    while got.count(b"\n\n") < 3:
        b1 = rv.read(1)
        assert b1, "victim stream ended early"
        got += b1
    rv.close()
    cv.close()
    # the other stream keeps flowing to completion, tokens intact
    events = _sse_events(rs.read())
    cs.close()
    assert events[-1] == "[DONE]"
    deltas = [json.loads(e)["choices"][0]["delta"].get("content")
              for e in events[:-1]]
    deltas = [d for d in deltas if d is not None]
    assert "".join(deltas) == " ".join(str(t) for t in direct)
    # abort released the victim's blocks: pool returns to baseline
    deadline = time.time() + 30
    while eng.kv_block_counts()[0] != free0 and time.time() < deadline:
        time.sleep(0.05)
    assert eng.kv_block_counts()[0] == free0
    assert gw.counters["disconnects"] >= 1
    assert eng.stats["aborts"] >= 1


@pytest.mark.cluster
def test_cluster_2e1p1d_gateway_parity(setup):
    """Greedy completions through a gateway fronting true EPD
    disaggregation are token-identical to a direct engine submit."""
    cfg, params = setup
    ecfg = EngineConfig(n_encode_workers=2, decode_batch=2)
    ref_eng = EPDEngine(cfg, params, ecfg)
    ref_eng.start()
    try:
        direct = _direct_tokens(cfg, ref_eng, PAYLOAD)
    finally:
        ref_eng.stop()

    cluster = ClusterEngine(cfg, params, ecfg,
                            ClusterConfig(spec="2E1P1D"))
    cluster.start()
    gw = GatewayServer(cluster, request_timeout=300.0).start()
    try:
        st, resp = _post(gw, PAYLOAD)
        assert st == 200
        assert resp["choices"][0]["token_ids"] == direct
        st, r, c = _post(gw, dict(PAYLOAD, stream=True), stream=True)
        assert st == 200
        events = _sse_events(r.read())
        c.close()
        assert events[-1] == "[DONE]"
        deltas = [json.loads(e)["choices"][0]["delta"].get("content")
                  for e in events[:-1]]
        assert "".join(d for d in deltas if d) == " ".join(
            str(t) for t in direct)
        st, h = _get(gw, "/health")
        assert st == 200 and h["ok"]
    finally:
        gw.stop()
        cluster.stop()


def test_gateway_smoke(gateway):
    """CI fast-tier node: one unary + one SSE + one 400 on an ephemeral
    port, then clean shutdown (the fixture's teardown)."""
    _, _, gw = gateway
    assert gw.port != 0
    st, resp = _post(gw, PAYLOAD)
    assert st == 200 and len(resp["choices"][0]["token_ids"]) == 6
    st, r, c = _post(gw, dict(PAYLOAD, stream=True), stream=True)
    events = _sse_events(r.read())
    c.close()
    assert st == 200 and events[-1] == "[DONE]"
    st, resp = _post(gw, {"messages": []})
    assert st == 400
