"""Black-box allocation optimizer (paper §3.2.3, Table 5 mechanics)."""
import numpy as np
import pytest

from repro.core.allocator import (AllocConfig, _GP, optimize_allocation,
                                  sample_configs)


def test_sampled_configs_respect_budget():
    rng = np.random.default_rng(0)
    for c in sample_configs(rng, 64, n_gpus=8):
        assert c.n_gpus == 8
        assert c.n_e >= 1 and c.n_p >= 1 and c.n_d >= 1


def test_spec_string_roundtrip():
    c = AllocConfig(5, 2, 1, 8, 8, 128, True)
    assert c.spec().spec == "5E2P1D"
    assert c.spec().roles() == ["E"] * 5 + ["P"] * 2 + ["D"]


def test_gp_fits_and_predicts():
    rng = np.random.default_rng(1)
    X = rng.standard_normal((20, 3))
    y = X[:, 0] * 2 + np.sin(X[:, 1])
    gp = _GP()
    gp.fit(X, y)
    mean, std = gp.predict(X)
    assert np.corrcoef(mean, y)[0, 1] > 0.95
    assert np.all(std >= 0)


def test_bo_beats_random_mean_on_synthetic_objective():
    """Table 5's mechanism: the optimizer finds configs clearly better than
    the random-sample average."""
    def objective(c: AllocConfig) -> float:
        # synthetic goodput: encode-heavy workload likes many E workers and
        # IRP, decode needs at least one D
        score = min(c.n_e / 5.0, 1.0) + 0.3 * float(c.irp) \
            + 0.2 * min(c.n_d, 2) - 0.1 * abs(c.n_p - 1)
        return score

    res = optimize_allocation(objective, n_gpus=8, n_init=6, n_iter=10,
                              seed=3)
    rng = np.random.default_rng(9)
    rand_scores = [objective(c) for c in sample_configs(rng, 10, n_gpus=8)]
    assert res.best_score > np.mean(rand_scores)
    assert res.best.n_e >= 4          # it should discover encode-heaviness


def test_cost_penalty_prefers_fewer_gpus():
    def objective(c):
        return 1.0  # flat performance
    res = optimize_allocation(objective, n_gpus=8, n_init=8, n_iter=8,
                              seed=0, beta=0.1)
    # with flat f, the penalty dominates; all configs cost the same 8 GPUs
    # under exact_gpus, so score must equal 1 - 0.8
    assert res.best_score == pytest.approx(1.0 - 0.8)
