"""End-to-end behaviour tests for the EPD system (paper headline claims).

These assert the reproduction's qualitative results on the full pipeline:
memory savings from disaggregation (§4.3), more images/request (Table 2),
bigger batches (Table 3), larger KV caches (Table 8), and goodput dominance
(Fig 5) — each as a system invariant rather than a point estimate.
"""
import pytest

from repro.configs import get_config
from repro.core import A100_80G, SLO, simulate, summarize
from repro.core.cluster import ClusterSpec
from repro.core.instance import Instance
from repro.core import costmodel as cm
from repro.data.workload import WorkloadSpec, poisson_requests

MINICPM = get_config("minicpm-v-2.6")
IVL8 = get_config("internvl2-8b")
IVL26 = get_config("internvl2-26b")


# ------------------------------------------------------------- §4.3 memory
@pytest.mark.parametrize("cfg,min_saving", [
    (MINICPM, 0.90), (IVL8, 0.90), (IVL26, 0.70)])
def test_encode_worker_weight_savings(cfg, min_saving):
    """E workers drop the LLM weights: ~95% / 96.2% / 78.3% smaller."""
    full = cm.weights_bytes(cfg)
    enc_only = cm.weights_bytes(cfg, include_llm=False)
    assert 1 - enc_only / full >= min_saving


def test_e_instance_has_no_kv_cache():
    e = Instance("E", 1, MINICPM, A100_80G)
    p = Instance("P", 1, MINICPM, A100_80G)
    d = Instance("D", 1, MINICPM, A100_80G)
    assert e.kv_cache is None and e.mm_cache is not None
    assert p.kv_cache is not None and p.mm_cache is not None
    assert d.kv_cache is not None and d.mm_cache is None


def test_disaggregated_memory_headroom():
    """§4.3: E workers hit ~15x lower peak memory utilization (weights +
    KV-cache reservation vs encoder weights only)."""
    agg = Instance("EP", 1, MINICPM, A100_80G, kv_frac=0.8)
    enc = Instance("E", 1, MINICPM, A100_80G)
    used_agg = agg.weights_bytes() + agg.kv_cache.n_blocks \
        * agg.kv_cache.block_size * MINICPM.kv_bytes_per_token(cm.DTYPE_BYTES)
    used_enc = enc.weights_bytes()
    assert used_agg / used_enc > 10.0
    assert enc.free_memory() > agg.free_memory()


# --------------------------------------------------- Table 2/3-style limits
def _max_images(cfg, role: str, kv_frac=0.8) -> int:
    inst = Instance(role, 1, cfg, A100_80G, kv_frac=kv_frac)
    free = inst.free_memory()
    if inst.kv_cache is not None:
        free -= inst.kv_cache.n_blocks * inst.kv_cache.block_size \
            * cfg.kv_bytes_per_token(cm.DTYPE_BYTES)
    per_patch = cm.encode_activation_bytes(cfg, 1) \
        + cm.mm_token_bytes(cfg, cfg.modality.tokens_per_item)
    patches = cfg.modality.patches_at_res[(4032, 3024)]
    return max(0, int(free / (per_patch * patches)))


@pytest.mark.parametrize("cfg", [MINICPM, IVL8, IVL26])
def test_epd_supports_more_images_per_request(cfg):
    assert _max_images(cfg, "E") > 2 * max(1, _max_images(cfg, "EP"))


# ----------------------------------------------------------- Fig 5 goodput
def test_epd_dominates_slo_attainment_curve():
    slo = SLO(ttft=1.40, tpot=0.04)
    for rate in (0.25, 0.5, 1.0):
        reqs = poisson_requests(MINICPM, WorkloadSpec(
            rate=rate, n_requests=50, n_items=2, output_len=10, slo=slo))
        epd = summarize(simulate(ClusterSpec("5E2P1D"), MINICPM,
                                 A100_80G, reqs), slo)
        dist = summarize(simulate(ClusterSpec("7EP1D", irp=False), MINICPM,
                                  A100_80G, reqs), slo)
        assert epd.slo_attainment >= dist.slo_attainment


def test_more_images_hurts_baselines_more():
    """Fig 5 rows: going 2 -> 4 images degrades DistServe faster than EPD."""
    slo = SLO(ttft=2.60, tpot=0.04)
    out = {}
    for items in (2, 4):
        reqs = poisson_requests(MINICPM, WorkloadSpec(
            rate=0.5, n_requests=50, n_items=items, output_len=10, slo=slo))
        out[("epd", items)] = summarize(simulate(
            ClusterSpec("5E2P1D"), MINICPM, A100_80G, reqs), slo).slo_attainment
        out[("dist", items)] = summarize(simulate(
            ClusterSpec("7EP1D", irp=False), MINICPM, A100_80G, reqs),
            slo).slo_attainment
    drop_epd = out[("epd", 2)] - out[("epd", 4)]
    drop_dist = out[("dist", 2)] - out[("dist", 4)]
    assert drop_dist >= drop_epd - 0.02


# --------------------------------------------------------------- App A.2
def test_p_worker_kv_budget_larger_without_encoder():
    """Table 8: the P worker in EPD (no encoder weights/activations) can
    dedicate more memory to KV cache than the aggregated EP worker."""
    p = Instance("P", 1, IVL26, A100_80G, kv_frac=0.8)
    ep = Instance("EP", 1, IVL26, A100_80G, kv_frac=0.8)
    assert p.kv_cache.n_blocks > ep.kv_cache.n_blocks
