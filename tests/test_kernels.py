"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.mamba2_scan import mamba2_ssd, mamba2_ssd_ref
from repro.kernels.rwkv6_scan import rwkv6_wkv, rwkv6_wkv_ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,K,Sq,Sk,hd", [
    (2, 4, 2, 128, 128, 64),
    (1, 4, 4, 100, 100, 64),     # ragged, MHA
    (2, 8, 2, 256, 256, 128),
    (1, 2, 1, 64, 192, 64),      # cross-attn shape (Sq != Sk)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_prefill(B, H, K, Sq, Sk, hd, dtype, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires square")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, hd), jnp.float32).astype(dtype)
    out = flash_prefill(q, k, v, causal=causal, interpret=True)
    ref = flash_prefill_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


def test_flash_prefill_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    out = flash_prefill(q, k, v, causal=True, window=64, interpret=True)
    ref = flash_prefill_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,K,W,hd", [
    (2, 4, 2, 300, 64),
    (1, 8, 8, 512, 128),
    (3, 16, 2, 1000, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn(B, H, K, W, hd, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, W, K, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, W, K, hd), jnp.float32).astype(dtype)
    length = jax.random.randint(ks[3], (B,), 1, W + 1, jnp.int32)
    out = decode_attn(q, kc, vc, length, interpret=True)
    ref = decode_attn_ref(q, kc, vc, length)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 64, 64, 32),
    (1, 96, 2, 64, 32, 32),
    (2, 256, 8, 64, 64, 64),
])
def test_mamba2_ssd(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    a = -dt * jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))[None, None] * 0.5
    bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y, st = mamba2_ssd(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, sr = mamba2_ssd_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, sr, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,H,P,chunk", [
    (2, 128, 4, 64, 32),
    (1, 96, 2, 64, 32),
    (2, 64, 8, 64, 64),
])
def test_rwkv6_wkv(B, S, H, P, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, P), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, P), jnp.float32)))
    u = jax.random.normal(ks[4], (H, P), jnp.float32) * 0.5
    y, st = rwkv6_wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = rwkv6_wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(st, sr, rtol=3e-3, atol=3e-3)


def test_rwkv6_strong_decay_no_overflow():
    """log-space chunking must survive decays that would overflow the naive
    k*exp(-cum) factorization."""
    B, S, H, P = 1, 64, 1, 64
    ks = jax.random.split(KEY, 3)
    r = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, P), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32)
    w = jnp.full((B, S, H, P), 0.01, jnp.float32)    # 0.01^64 ~ 1e-128
    u = jnp.zeros((H, P), jnp.float32)
    y, st = rwkv6_wkv(r, k, v, w, u, chunk=64, interpret=True)
    assert bool(jnp.all(jnp.isfinite(y)))
    yr, _ = rwkv6_wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,H,K,N,bs,mb,hd", [
    (2, 4, 2, 16, 32, 4, 64),
    (1, 8, 8, 32, 16, 8, 128),
    (3, 16, 4, 24, 64, 3, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attn(B, H, K, N, bs, mb, hd, dtype):
    from repro.kernels.paged_attn import paged_decode_attn, paged_decode_attn_ref
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, bs, K, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, bs, K, hd), jnp.float32).astype(dtype)
    tables = jax.random.permutation(ks[3], N)[:B * mb].reshape(B, mb)
    lengths = jax.random.randint(ks[4], (B,), 1, mb * bs + 1, jnp.int32)
    out = paged_decode_attn(q, kp, vp, tables, lengths, interpret=True)
    ref = paged_decode_attn_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


def test_paged_matches_contiguous_decode():
    """Block-table indirection must be transparent: paged attention over a
    shuffled pool == contiguous decode attention."""
    from repro.kernels.decode_attn import decode_attn_ref
    from repro.kernels.paged_attn import paged_decode_attn
    ks = jax.random.split(KEY, 4)
    B, H, K, hd, bs, mb = 2, 4, 2, 64, 16, 4
    kc = jax.random.normal(ks[0], (B, mb * bs, K, hd), jnp.float32)
    vc = jax.random.normal(ks[1], (B, mb * bs, K, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    lengths = jnp.array([37, 64], jnp.int32)
    # scatter the contiguous caches into a shuffled pool
    N = B * mb
    tables = jax.random.permutation(ks[3], N).reshape(B, mb)
    kp = jnp.zeros((N, bs, K, hd)).at[tables.reshape(-1)].set(
        kc.reshape(N, bs, K, hd))
    vp = jnp.zeros((N, bs, K, hd)).at[tables.reshape(-1)].set(
        vc.reshape(N, bs, K, hd))
    out = paged_decode_attn(q, kp, vp, tables, lengths, interpret=True)
    ref = decode_attn_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
