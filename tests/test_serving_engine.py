"""Real-execution EPD engine: tiny end-to-end serve on CPU."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import EPDEngine, EngineConfig, ServeRequest


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("pixtral-12b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=2, max_new_tokens=4, decode_batch=2))
    eng.start()
    yield cfg, eng
    eng.stop()


def test_multimodal_request_roundtrip(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    M = 6
    req = ServeRequest(
        req_id=1,
        prompt=rng.integers(0, cfg.vocab, 16).astype(np.int32),
        mm_embeds=rng.standard_normal(
            (M, cfg.modality.enc_d_model)).astype(np.float32) * 0.1,
        mm_positions=np.arange(1, M + 1, dtype=np.int32),
        max_new_tokens=4)
    eng.submit(req)
    out = eng.result(1, timeout=300)
    assert len(out.tokens) == 4
    assert all(0 <= t < cfg.vocab for t in out.tokens)
    assert out.t_encoded >= out.t_submit
    assert out.t_first_token >= out.t_encoded
    assert out.t_done >= out.t_first_token


def test_text_only_request_skips_encode(engine):
    cfg, eng = engine
    rng = np.random.default_rng(1)
    req = ServeRequest(req_id=2,
                       prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                       max_new_tokens=3)
    eng.submit(req)
    out = eng.result(2, timeout=300)
    assert len(out.tokens) == 3


def test_irp_sharding_is_lossless(engine):
    """IRP correctness: patch-sharded encoding must equal 1-shot encoding —
    the paper's align/project/merge relies on patches being encoded
    independently (block-diagonal encoder attention)."""
    cfg, eng = engine
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    tpi = cfg.modality.tokens_per_item
    M = 2 * tpi                                   # two patch groups
    mm = rng.standard_normal((M, cfg.modality.enc_d_model)).astype(np.float32)
    encode = eng.encode_stage.encode_fn
    whole = np.asarray(encode(eng.params, jnp.asarray(mm)[None])[0],
                       np.float32)
    half1 = np.asarray(encode(eng.params, jnp.asarray(mm[:tpi])[None])[0],
                       np.float32)
    half2 = np.asarray(encode(eng.params, jnp.asarray(mm[tpi:])[None])[0],
                       np.float32)
    merged = np.concatenate([half1, half2], axis=0)
    np.testing.assert_allclose(merged, whole, rtol=2e-2, atol=2e-2)
