"""API frontend schema + load estimator tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100_80G
from repro.core.load_estimator import LoadEstimator
from repro.core.request import Request
from repro.serving.api import (APIError, IncrementalDetokenizer,
                               build_chat_chunk, build_chat_response,
                               parse_chat_request, to_sim_request)

PIXTRAL = get_config("pixtral-12b")
TEXT = get_config("internlm2-20b")


def _img(cfg, tokens=4):
    return {"type": "image_embedding",
            "embedding": np.zeros((tokens, cfg.modality.enc_d_model)).tolist()}


def test_parse_text_and_image():
    req = parse_chat_request(PIXTRAL, {
        "messages": [{"role": "user", "content": [
            {"type": "text", "text": "describe this image"},
            _img(PIXTRAL)]}],
        "max_tokens": 8})
    assert req.max_new_tokens == 8
    assert req.prompt.shape == (3,)
    assert req.mm_embeds.shape == (4, PIXTRAL.modality.enc_d_model)
    assert list(req.mm_positions) == [1, 2, 3, 4]


def test_plain_string_content():
    req = parse_chat_request(TEXT, {"messages": [
        {"role": "user", "content": "hello world"}]})
    assert req.prompt.shape == (2,) and req.mm_embeds is None


@pytest.mark.parametrize("payload,msg", [
    ({}, "missing messages"),
    ({"messages": []}, "missing messages"),
    ({"messages": [{"role": "u", "content": [{"type": "bogus"}]}]}, "unknown"),
    ({"messages": [{"role": "u", "content": "x"}], "max_tokens": 0}, "range"),
    ({"messages": [{"role": "u", "content": "x"}], "max_tokens": 9000},
     "range"),
    ({"messages": [{"role": "u", "content": "x"}], "temperature": 9}, "range"),
    ({"messages": [{"role": "u", "content": "x"}], "temperature": -0.1},
     "range"),
    ({"messages": [{"role": "u", "content": "x"}], "top_p": 0.0}, "range"),
    ({"messages": [{"role": "u", "content": "x"}], "top_p": 1.5}, "range"),
    ({"messages": [{"role": "u", "content": "x"}], "seed": -1}, "uint32"),
    ({"messages": [{"role": "u", "content": "x"}], "seed": 2 ** 33},
     "uint32"),
])
def test_rejects_bad_payloads(payload, msg):
    with pytest.raises(APIError, match=msg):
        parse_chat_request(TEXT, payload)


def test_rejects_image_for_text_model():
    with pytest.raises(APIError, match="text-only"):
        parse_chat_request(TEXT, {"messages": [
            {"role": "u", "content": [_img(PIXTRAL)]}]})


def test_rejects_wrong_embedding_width():
    bad = {"type": "image_embedding", "embedding": [[0.0] * 7]}
    with pytest.raises(APIError, match="embedding must be"):
        parse_chat_request(PIXTRAL, {"messages": [
            {"role": "u", "content": [bad]}]})


def test_context_limit_oocl():
    mini = get_config("minicpm-v-2.6")  # ctx 32768
    with pytest.raises(APIError, match="OOCL"):
        parse_chat_request(mini, {
            "messages": [{"role": "u", "content": [
                {"type": "text", "text": "q"},
                _img(mini, tokens=40_000)]}]})


def test_build_chat_response_usage_and_timings():
    req = parse_chat_request(PIXTRAL, {
        "messages": [{"role": "user", "content": [
            {"type": "text", "text": "a b c"}, _img(PIXTRAL)]}],
        "max_tokens": 8})
    req.t_submit, req.t_first_token, req.t_done = 1.0, 1.5, 2.5
    req.mm_cache_hit = True
    for t in (11, 22, 33):
        req.tokens.append(t)
    resp = build_chat_response(PIXTRAL, req)
    assert resp["object"] == "chat.completion"
    assert resp["id"] == f"chatcmpl-{req.req_id}"
    assert resp["choices"][0]["message"]["content"] == "11 22 33"
    assert resp["choices"][0]["token_ids"] == [11, 22, 33]
    # usage counts mm tokens as prompt tokens
    assert resp["usage"] == {"prompt_tokens": 3 + 4,
                             "completion_tokens": 3, "total_tokens": 10}
    t = resp["timings"]
    assert t["ttft"] == pytest.approx(0.5)
    assert t["tpot"] == pytest.approx(0.5)       # (2.5 - 1.5) / (3 - 1)
    assert t["n_preemptions"] == 0 and t["mm_cache_hit"] is True


def test_incremental_detokenizer_matches_response_content():
    toks = [5, 17, 0, 999]
    detok = IncrementalDetokenizer()
    assembled = "".join(detok.feed(t) for t in toks)
    assert assembled == " ".join(str(t) for t in toks)


def test_build_chat_chunk_shapes():
    req = parse_chat_request(TEXT, {"messages": [
        {"role": "u", "content": "x"}]})
    first = build_chat_chunk(TEXT, req, role=True)
    assert first["object"] == "chat.completion.chunk"
    assert first["choices"][0]["delta"] == {"role": "assistant"}
    mid = build_chat_chunk(TEXT, req, " 42")
    assert mid["choices"][0]["delta"] == {"content": " 42"}
    assert mid["choices"][0]["finish_reason"] is None
    last = build_chat_chunk(TEXT, req, finish_reason="length")
    assert last["choices"][0]["delta"] == {}
    assert last["choices"][0]["finish_reason"] == "length"


def test_to_sim_request():
    r = to_sim_request(PIXTRAL, {"messages": [
        {"role": "u", "content": [
            {"type": "text", "text": "a b c"},
            _img(PIXTRAL, tokens=2 * PIXTRAL.modality.tokens_per_item)]}],
        "max_tokens": 4}, arrival=1.5)
    assert isinstance(r, Request)
    assert r.prompt_len == 3 and r.n_items == 2 and r.output_len == 4


# -------------------------------------------------------- load estimator
def _mk(i, t, items=2, out=10):
    return Request(req_id=i, arrival=t, prompt_len=22, n_items=items,
                   patches_per_item=10, tokens_per_patch=64, output_len=out)


def test_estimator_demand_tracks_stage_mix():
    cfg = get_config("minicpm-v-2.6")
    est = LoadEstimator(cfg, A100_80G)
    t = 0.0
    for i in range(20):
        est.observe(_mk(i, t), t)
        t += 0.5                      # 2 req/s
    d = est.stage_demand()
    assert d["E"] > 0 and d["P"] > 0 and d["D"] > 0
    assert d["E"] > d["P"]            # 4K-image workload is encode-heavy


def test_estimator_allocation_sums_and_shifts():
    cfg = get_config("minicpm-v-2.6")
    est = LoadEstimator(cfg, A100_80G)
    t = 0.0
    for i in range(20):
        est.observe(_mk(i, t, out=10), t)
        t += 0.5
    alloc_short = est.suggest_allocation(8)
    assert sum(alloc_short.values()) == 8
    assert alloc_short["E"] >= alloc_short["D"]
    # workload shifts to long outputs -> decode demand grows (Table 6 story)
    for i in range(60):
        est.observe(_mk(100 + i, t, out=800), t)
        t += 0.5
    alloc_long = est.suggest_allocation(8)
    assert sum(alloc_long.values()) == 8
    assert alloc_long["D"] > alloc_short["D"]
