"""Stop-token finish semantics end-to-end (tentpole rider): a sampled
token matching ``SamplingParams.stop_tokens``/``eos_id`` produces
``FinishReason.STOP`` with the matched token excluded (OpenAI "stop"
semantics) — identical across dense/paged engines, under seeded
sampling, across a forced preemption replay, and through streaming.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EPDEngine, EngineConfig, FinishReason,
                           RequestState, SamplingParams, ServeRequest)
from repro.serving.api import chat_completion, parse_chat_request
from repro.serving.types import APIError


@pytest.fixture(scope="module")
def text_setup():
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, prompt, max_new, sampling=SamplingParams(),
         mode="paged", **ecfg_kw):
    kw = dict(decode_batch=2, kv_blocks=32, max_seq_len=64, mode=mode)
    kw.update(ecfg_kw)
    eng = EPDEngine(cfg, params, EngineConfig(**kw))
    eng.start()
    try:
        eng.submit(ServeRequest(req_id=1, prompt=prompt.copy(),
                                max_new_tokens=max_new, sampling=sampling))
        return eng.result(1, timeout=300), eng
    finally:
        eng.stop()


def _prompt(cfg, n=12, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab, n) \
        .astype(np.int32)


def test_greedy_stop_identical_across_modes(text_setup):
    """Pick the 4th greedy token as the stop token: both engines must
    emit exactly the first 3 tokens and finish with STOP."""
    cfg, params = text_setup
    prompt = _prompt(cfg)
    ref, _ = _run(cfg, params, prompt, 8)
    assert ref.finish_reason is FinishReason.LENGTH
    stop = ref.tokens[3]
    expect = ref.tokens[:ref.tokens.index(stop)]
    for mode in ("paged", "dense"):
        out, _ = _run(cfg, params, prompt, 8, mode=mode,
                      sampling=SamplingParams(stop_tokens=(stop,)))
        assert out.tokens == expect, mode
        assert out.finish_reason is FinishReason.STOP, mode
        assert not any(t == stop for t in out.tokens)


def test_eos_id_finishes_with_stop(text_setup):
    cfg, params = text_setup
    prompt = _prompt(cfg, seed=1)
    ref, _ = _run(cfg, params, prompt, 6)
    out, _ = _run(cfg, params, prompt, 6,
                  sampling=SamplingParams(eos_id=ref.tokens[2]))
    assert out.tokens == ref.tokens[:ref.tokens.index(ref.tokens[2])]
    assert out.finish_reason is FinishReason.STOP


def test_stop_at_first_token_yields_empty_output(text_setup):
    """The stop token can be the prefill's first sample: zero tokens,
    STOP, and the request still flows through D's retire path cleanly."""
    cfg, params = text_setup
    prompt = _prompt(cfg, seed=2)
    ref, _ = _run(cfg, params, prompt, 4)
    out, eng = _run(cfg, params, prompt, 4,
                    sampling=SamplingParams(stop_tokens=(ref.tokens[0],)))
    assert out.tokens == [] and out.finish_reason is FinishReason.STOP
    assert eng.kv_mgr.used_blocks == 0


def test_seeded_sampling_stop_is_deterministic(text_setup):
    """Nucleus-sampled stop in both engines: same seed -> same truncated
    output + STOP. Each mode is compared against its own seeded reference
    (the two decode kernels differ by float ULPs, which can flip samples
    near a nucleus boundary — greedy cross-mode parity is covered above)."""
    cfg, params = text_setup
    prompt = _prompt(cfg, seed=3)
    samp = SamplingParams(temperature=0.9, top_p=0.9, seed=71)
    for mode in ("paged", "dense"):
        ref, _ = _run(cfg, params, prompt, 8, sampling=samp, mode=mode)
        assert len(ref.tokens) == 8, mode
        stop = ref.tokens[4]
        stop_samp = SamplingParams(temperature=0.9, top_p=0.9, seed=71,
                                   stop_tokens=(stop,))
        out, _ = _run(cfg, params, prompt, 8, sampling=stop_samp,
                      mode=mode)
        # sampling is keyed on (seed, token index): the prefix matches
        # the unstopped run exactly, then the stop token is excluded
        assert out.tokens == ref.tokens[:ref.tokens.index(stop)], mode
        assert out.finish_reason is FinishReason.STOP, mode


def test_stop_survives_preemption_replay(text_setup):
    """A preempted request's deterministic replay must re-derive the
    same stop decision (tokens + STOP) as an uncontended run."""
    cfg, params = text_setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 15).astype(np.int32)
               for _ in range(2)]
    # uncontended reference (big pool)
    refs = []
    for i, p in enumerate(prompts):
        out, _ = _run(cfg, params, p, 8, kv_blocks=32)
        refs.append(out.tokens)
    stops = [r[6] for r in refs]
    # tight pool (3 blocks): the first append crosses a block boundary,
    # so two concurrent requests force a preemption (same geometry as
    # test_out_of_blocks_preempts_and_recovers)
    eng = EPDEngine(cfg, params, EngineConfig(
        n_encode_workers=1, decode_batch=2, kv_blocks=3, kv_block_size=16,
        max_seq_len=64))
    eng.start()
    try:
        for i, p in enumerate(prompts):
            eng.submit(ServeRequest(
                req_id=i + 1, prompt=p.copy(), max_new_tokens=8,
                sampling=SamplingParams(stop_tokens=(stops[i],))))
        outs = [eng.result(i + 1, timeout=300) for i in range(2)]
    finally:
        eng.stop()
    assert eng.stats["preemptions"] >= 1
    for i, out in enumerate(outs):
        assert out.tokens == refs[i][:refs[i].index(stops[i])]
        assert out.finish_reason is FinishReason.STOP


def test_streaming_terminates_on_stop_without_timeout(text_setup):
    """A stream over a stopped request ends cleanly (no timeout path):
    the stop token is never yielded."""
    cfg, params = text_setup
    prompt = _prompt(cfg, seed=5)
    ref, _ = _run(cfg, params, prompt, 6)
    stop = ref.tokens[2]
    expect = ref.tokens[:ref.tokens.index(stop)]
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=32, max_seq_len=64))
    eng.start()
    try:
        handle = eng.submit(ServeRequest(
            req_id=1, prompt=prompt.copy(), max_new_tokens=6,
            sampling=SamplingParams(stop_tokens=(stop,))))
        streamed = list(handle.stream(timeout=30))   # must not TimeoutError
        out = handle.result(timeout=30)
    finally:
        eng.stop()
    assert streamed == expect == out.tokens
    assert out.finish_reason is FinishReason.STOP
    assert out.state is RequestState.DONE


def test_long_prompt_chunked_stop(text_setup):
    """Stop tokens compose with chunked prefill: the first token sampled
    off the final chunk can itself be the stop."""
    cfg, params = text_setup
    prompt = _prompt(cfg, n=80, seed=6)
    ref, _ = _run(cfg, params, prompt, 4, kv_blocks=64, max_seq_len=128,
                  prefill_chunk=32)
    out, eng = _run(cfg, params, prompt, 4, kv_blocks=64, max_seq_len=128,
                    prefill_chunk=32,
                    sampling=SamplingParams(stop_tokens=(ref.tokens[0],)))
    assert eng.stats["prefill_chunks"] >= 3
    assert out.tokens == [] and out.finish_reason is FinishReason.STOP


def test_api_carries_stop_token_ids(text_setup):
    cfg, params = text_setup
    payload = {"messages": [{"role": "user",
                             "content": "alpha beta gamma delta"}],
               "max_tokens": 6}
    eng = EPDEngine(cfg, params, EngineConfig(
        decode_batch=2, kv_blocks=32, max_seq_len=64))
    eng.start()
    try:
        ref = chat_completion(eng, payload)
        ids = ref["choices"][0]["token_ids"]
        stopped = chat_completion(eng, dict(payload,
                                            stop_token_ids=[ids[1]]))
    finally:
        eng.stop()
    choice = stopped["choices"][0]
    assert choice["finish_reason"] == "stop"
    assert choice["token_ids"] == ids[:ids.index(ids[1])]
    assert stopped["usage"]["completion_tokens"] == len(choice["token_ids"])


def test_api_rejects_bad_stop_ids(text_setup):
    cfg, _ = text_setup
    with pytest.raises(APIError, match="stop/eos"):
        parse_chat_request(cfg, {
            "messages": [{"role": "user", "content": "x"}],
            "stop_token_ids": [-3]})
    with pytest.raises(APIError, match="stop/eos"):
        SamplingParams(eos_id=-1).validate()
