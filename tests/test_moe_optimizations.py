"""Beyond-paper MoE optimizations must preserve semantics:
grouped dispatch (per-data-shard) and expert padding give the same outputs
as the baseline global dispatch when capacity is not binding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_init

BASE = get_config("qwen3-moe-30b-a3b").reduced()   # 4 experts top-2, cf=4


def _cfg(**moe_kw):
    return dataclasses.replace(BASE, moe=dataclasses.replace(BASE.moe,
                                                             **moe_kw))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    cfg = _cfg(capacity_factor=8.0)   # drop-free
    params = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, params, x


def test_grouped_dispatch_matches_global(setup):
    cfg, params, x = setup
    y1, aux1 = moe_apply(params, x, cfg, dispatch_groups=0)
    y4, aux4 = moe_apply(params, x, cfg, dispatch_groups=4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y4, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert float(aux1) == pytest.approx(float(aux4), rel=1e-5)


def test_padded_experts_match_unpadded(setup):
    cfg, params, x = setup
    y_ref, _ = moe_apply(params, x, cfg)
    cfg_pad = _cfg(capacity_factor=8.0, pad_experts=8)
    params_pad = dict(params)
    E, d, f = 4, cfg.d_model, cfg.d_ff
    for name, axis_shape in (("wi_gate", (8, d, f)), ("wi_up", (8, d, f)),
                             ("wo", (8, f, d))):
        pad = jnp.zeros((4,) + params[name].shape[1:], params[name].dtype)
        params_pad[name] = jnp.concatenate([params[name], pad], axis=0)
        assert params_pad[name].shape == axis_shape
    y_pad, _ = moe_apply(params_pad, x, cfg_pad)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pad, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_padded_init_shapes():
    cfg = _cfg(pad_experts=8)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    assert params["wi_gate"].shape[0] == 8
    assert params["router"].shape[1] == 4      # routing over real experts


def test_grouped_dispatch_gradients_finite(setup):
    cfg, params, x = setup

    def loss(p):
        y, aux = moe_apply(p, x, cfg, dispatch_groups=4)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
