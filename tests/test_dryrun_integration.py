"""Dry-run integration: lower+compile one (arch x shape) per mesh in a
subprocess (the 512-device XLA flag must not leak into this process), and
validate the recorded roofline JSONLs cover all 40 x 2 combinations."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import ASSIGNED, INPUT_SHAPES

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs")


@pytest.mark.parametrize("flags", [[], ["--multi-pod"]])
def test_dryrun_single_combo_compiles(flags):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "minitron-4b", "--shape", "decode_32k", *flags]
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["flops_per_device"] > 0
    assert rec["chips"] == (512 if flags else 256)


@pytest.mark.parametrize("fname,mesh", [
    ("dryrun_baseline.jsonl", "16x16"),
    ("dryrun_multipod.jsonl", "2x16x16"),
])
def test_sweep_covers_all_40_combinations(fname, mesh):
    path = os.path.join(RUNS, fname)
    if not os.path.exists(path):
        pytest.skip(f"{fname} not generated yet (run runs/sweep.sh)")
    recs = [json.loads(l) for l in open(path) if l.strip()]
    combos = {(r["arch"], r["shape"]) for r in recs}
    want = {(a, s) for a in ASSIGNED for s in INPUT_SHAPES}
    assert combos == want, f"missing: {want - combos}"
    assert all(r["mesh"] == mesh for r in recs)
    for r in recs:
        assert r["flops_per_device"] > 0
        assert r["roofline_s"][r["dominant"]] >= max(
            r["roofline_s"].values()) - 1e-12
