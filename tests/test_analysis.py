"""Analyzer goldens, baseline workflow, and lock-sanitizer unit tests.

The fixture snippets in ``tests/analysis_fixtures/`` are excluded from
normal reprolint runs (``DEFAULT_EXCLUDED_DIRS``) and scanned only
here, each pinned to the exact finding keys it must produce — plus a
``clean.py`` that must produce none (false-positive canary).
"""
import json
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis import lock_sanitizer
from repro.analysis.baseline import BaselineError
from repro.analysis.cli import analyze_paths
from repro.analysis.findings import Finding
from repro.analysis.lock_sanitizer import LockOrderViolation, Sanitizer

FIXDIR = Path(__file__).parent / "analysis_fixtures"

GOLDEN = {
    "lock_cycle.py": {"RL001", "RL004"},
    "blocking_under_lock.py": {"RL002"},
    "wait_without_predicate.py": {"RL003"},
    "host_sync_in_jit.py": {"RJ101"},
    "unbucketed_jit.py": {"RJ103"},
    "mutable_capture.py": {"RJ102"},
    "clean.py": set(),
}


# ------------------------------------------------------------- goldens
@pytest.mark.parametrize("name,keys", sorted(GOLDEN.items()))
def test_golden_fixture_keys(name, keys):
    findings = analyze_paths([FIXDIR / name], FIXDIR)
    assert {f.key for f in findings} == keys, \
        "\n".join(f.format() for f in findings)


def test_lock_cycle_flags_both_orders():
    findings = analyze_paths([FIXDIR / "lock_cycle.py"], FIXDIR)
    cycles = [f for f in findings if f.key == "RL001"]
    assert {f.symbol for f in cycles} == {"forward", "backward"}


def test_blocking_under_lock_flags_each_call():
    findings = analyze_paths([FIXDIR / "blocking_under_lock.py"], FIXDIR)
    msgs = " | ".join(f.message for f in findings)
    assert "time.sleep" in msgs
    assert "join" in msgs
    assert "without timeout" in msgs


def test_fixtures_are_excluded_from_repo_scans():
    # scanning tests/ at large must NOT pick up the bad snippets
    tests_dir = Path(__file__).parent
    findings = analyze_paths([tests_dir], tests_dir.parent)
    assert not any("analysis_fixtures" in f.path for f in findings)


# ------------------------------------------------------ baseline flow
def _finding(key="RJ103", path="src/x.py", line=3, symbol="f",
             message="msg"):
    return Finding(key, path, line, symbol, message)


def test_baseline_write_then_load_requires_real_why(tmp_path):
    p = tmp_path / "b.json"
    baseline_mod.write(p, [_finding()])
    with pytest.raises(BaselineError):
        baseline_mod.load(p)          # why is still "TODO"
    entries = json.loads(p.read_text())
    entries[0]["why"] = "parity oracle, retraces by design"
    p.write_text(json.dumps(entries))
    assert len(baseline_mod.load(p)) == 1


def test_baseline_match_is_line_number_independent(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps([{"key": "RJ103", "path": "src/x.py",
                              "symbol": "f", "why": "justified"}]))
    entries = baseline_mod.load(p)
    active, suppressed, stale = baseline_mod.apply(
        [_finding(line=999)], entries)
    assert not active and len(suppressed) == 1 and not stale


def test_baseline_stale_entry_is_reported(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps([{"key": "RJ103", "path": "src/x.py",
                              "symbol": "gone", "why": "justified"}]))
    entries = baseline_mod.load(p)
    active, suppressed, stale = baseline_mod.apply([_finding()], entries)
    assert len(active) == 1 and not suppressed and len(stale) == 1


def test_baseline_rejects_missing_fields(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps([{"key": "RJ103", "path": "src/x.py"}]))
    with pytest.raises(BaselineError):
        baseline_mod.load(p)


def test_cli_exit_codes(capsys):
    from repro.analysis import cli
    assert cli.main(["tests/analysis_fixtures/clean.py",
                     "--no-baseline"]) == 0
    assert cli.main(["tests/analysis_fixtures/lock_cycle.py",
                     "--no-baseline"]) == 1
    assert cli.main(["--keys"]) == 0
    capsys.readouterr()


# -------------------------------------------------- sanitizer (unit)
def test_sanitizer_records_declared_edge_without_violation():
    s = Sanitizer({}, {("a", "b")})
    s._record_push(1, "a")
    s._record_push(2, "b")
    assert ("a", "b") in s.witnessed
    assert not s.violations


def test_sanitizer_detects_inverted_order():
    s = Sanitizer({}, {("a", "b")})
    s._record_push(1, "a")
    s._record_push(2, "b")
    s._tls.stack.clear()
    s._record_push(2, "b")
    s._record_push(1, "a")            # b -> a closes the cycle
    assert len(s.violations) == 1
    assert ("b", "a") not in s.witnessed


def test_sanitizer_raise_mode():
    s = Sanitizer({}, set(), raise_on_violation=True)
    s._record_push(1, "a")
    s._record_push(2, "b")
    s._tls.stack.clear()
    s._record_push(2, "b")
    with pytest.raises(LockOrderViolation):
        s._record_push(1, "a")


def test_sanitizer_transitive_cycle():
    s = Sanitizer({}, {("a", "b"), ("b", "c")})
    s._record_push(1, "c")
    s._record_push(2, "a")            # c -> a cycles via declared chain
    assert len(s.violations) == 1


def test_sanitizer_unnamed_sites_produce_no_edges():
    s = Sanitizer({}, set())
    s._record_push(1, None)
    s._record_push(2, "b")
    s._record_push(3, None)
    assert not s.witnessed and not s.violations


# -------------------------------------------- sanitizer (integration)
def test_sanitizer_install_witnesses_named_nesting(tmp_path):
    if lock_sanitizer.active() is not None:
        pytest.skip("sanitizer already active session-wide")
    src = ("import threading\n"
           "a = threading.Lock()\n"
           "b = threading.RLock()\n"
           "def run():\n"
           "    with a:\n"
           "        with b:\n"
           "            with b:\n"          # reentry collapses
           "                pass\n"
           "run()\n")
    p = tmp_path / "snippet.py"
    p.write_text(src)
    table = {(str(p), 5): "outer.lock", (str(p), 6): "inner.lock",
             (str(p), 7): "inner.lock"}
    san = lock_sanitizer.install(site_table=table, declared=set())
    try:
        exec(compile(src, str(p), "exec"), {})
    finally:
        lock_sanitizer.uninstall()
    assert ("outer.lock", "inner.lock") in san.witnessed
    assert not san.violations
    assert san.acquisitions >= 2


def test_sanitizer_install_flags_inverted_order_at_runtime(tmp_path):
    if lock_sanitizer.active() is not None:
        pytest.skip("sanitizer already active session-wide")
    src = ("import threading\n"
           "a = threading.Lock()\n"
           "b = threading.Lock()\n"
           "def fwd():\n"
           "    with a:\n"
           "        with b:\n"
           "            pass\n"
           "def bwd():\n"
           "    with b:\n"
           "        with a:\n"
           "            pass\n"
           "fwd()\n"
           "bwd()\n")
    p = tmp_path / "snippet.py"
    p.write_text(src)
    table = {(str(p), 5): "a.lock", (str(p), 6): "b.lock",
             (str(p), 9): "b.lock", (str(p), 10): "a.lock"}
    san = lock_sanitizer.install(site_table=table, declared=set())
    try:
        exec(compile(src, str(p), "exec"), {})
    finally:
        lock_sanitizer.uninstall()
    assert ("a.lock", "b.lock") in san.witnessed
    assert len(san.violations) == 1 and "b.lock" in san.violations[0]
