"""Prefill-then-decode must match teacher-forced prefill logits.

For every architecture: prefill S tokens then decode token S must produce
the same next-token logits as prefilling S+1 tokens directly (within bf16
tolerance). This pins the KV-cache / recurrent-state semantics that the
EPD ψ_PD migration depends on.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.configs.base import InputShape
from repro.models import build_model, make_concrete_batch

S = 32


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_prefill(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    full = make_concrete_batch(cfg, InputShape("c", S + 1, 2, "prefill"),
                               rng_key)
    part = {k: (v[:, :S] if k == "tokens" else v) for k, v in full.items()}

    ref, _ = model.prefill(params, batch=full)
    kw = {} if cfg.family == "ssm" else {"max_len": S + 8}
    _, cache = model.prefill(params, batch=part, **kw)
    out, _ = model.decode_step(
        params, batch={"token": full["tokens"][:, S], "cache": cache})

    err = jnp.max(jnp.abs(ref.astype(jnp.float32) - out.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6
    assert float(err / scale) < 0.02, f"{arch}: rel err {float(err/scale)}"


def test_multi_step_decode_matches(rng_key):
    """Dense arch: 4 consecutive decode steps track teacher forcing."""
    cfg = get_config("codeqwen1.5-7b").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    full = make_concrete_batch(cfg, InputShape("c", S + 4, 1, "prefill"),
                               rng_key)
    part = {"tokens": full["tokens"][:, :S]}
    _, cache = model.prefill(params, batch=part, max_len=S + 8)
    for i in range(4):
        ref, _ = model.prefill(
            params, batch={"tokens": full["tokens"][:, :S + i + 1]})
        out, cache = model.decode_step(
            params, batch={"token": full["tokens"][:, S + i], "cache": cache})
        err = jnp.max(jnp.abs(ref.astype(jnp.float32)
                              - out.astype(jnp.float32)))
        assert float(err) < 0.1, f"step {i}: {float(err)}"


def test_sliding_window_decode(rng_key):
    """Ring-buffer cache: decode with window W attends only last W tokens."""
    cfg = get_config("minitron-4b").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    W = 16
    batch = make_concrete_batch(cfg, InputShape("c", S, 1, "prefill"), rng_key)
    _, cache = model.prefill(params, batch=batch, window=W)
    assert cache["k"].shape[2] == W
    tok = batch["tokens"][:, -1]
    logits, cache2 = model.decode_step(params,
                                       batch={"token": tok, "cache": cache})
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert cache2["k"].shape[2] == W
