"""Figure 6 / §4.2: first-token latency distributions per model; paper
claims EPD cuts TTFT up to 71.9% / 32.8% / 44.9% vs DistServe."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.cluster import ClusterSpec, simulate
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import (DIST_SPEC, EPD_SPEC, Row, engine_mode_stats,
                               timed)

RATES = {"minicpm-v-2.6": 0.25, "internvl2-8b": 0.08, "internvl2-26b": 0.08}
PAPER_REDUCTION = {"minicpm-v-2.6": 0.719, "internvl2-8b": 0.328,
                   "internvl2-26b": 0.449}


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n_req = 40 if quick else 100
    for model, rate in RATES.items():
        cfg = get_config(model)
        for n_img in ((2,) if quick else (2, 4)):
            reqs = poisson_requests(cfg, WorkloadSpec(
                rate=rate, n_requests=n_req, n_items=n_img, output_len=10))
            stats = {}
            for sysname, spec, irp in (("EPD", EPD_SPEC, True),
                                       ("DistServe", DIST_SPEC, False)):
                out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                                cfg, A100_80G, reqs)
                t = np.array([r.ttft for r in out])
                stats[sysname] = t
                rows.append(Row(
                    f"fig6/{model}/img{n_img}/{sysname}", us,
                    round(float(t.mean()), 4),
                    {"p50": float(np.percentile(t, 50)),
                     "p95": float(np.percentile(t, 95))}))
            red = 1 - stats["EPD"].mean() / stats["DistServe"].mean()
            rows.append(Row(
                f"sec4.2/{model}/img{n_img}/ttft_reduction", 0.0,
                round(float(red), 3),
                {"paper_reduction_upto": PAPER_REDUCTION[model]}))
    rows.extend(run_engine_ttft(quick))
    return rows


def run_engine_ttft(quick: bool = False) -> list[Row]:
    """Real-execution engine TTFT + decode tokens/s per decode-stage mode
    (paged-batched vs dense per-request), same reduced model + workload."""
    stats = engine_mode_stats(quick)
    rows = []
    for mode in ("paged", "dense"):
        s = stats[mode]
        rows.append(Row(f"engine_ttft/{mode}", s["wall_s"] * 1e6,
                        round(s["mean_ttft"], 4),
                        {"decode_tok_s": round(s["decode_tok_s"], 1),
                         "peak_cache_bytes": s["peak_cache_bytes"]}))
    return rows
