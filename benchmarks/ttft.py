"""Figure 6 / §4.2: first-token latency distributions per model; paper
claims EPD cuts TTFT up to 71.9% / 32.8% / 44.9% vs DistServe."""
from __future__ import annotations

if __package__ in (None, ""):
    # running as a script (python benchmarks/ttft.py): put the repo root
    # and src/ on sys.path so `benchmarks.common` and `repro` resolve
    # without an external PYTHONPATH
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import numpy as np

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.cluster import ClusterSpec, simulate
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import (DIST_SPEC, EPD_SPEC, Row, engine_mm_cache_stats,
                               engine_mode_stats, engine_overlap_stats,
                               engine_prefix_cache_stats, timed)

RATES = {"minicpm-v-2.6": 0.25, "internvl2-8b": 0.08, "internvl2-26b": 0.08}
PAPER_REDUCTION = {"minicpm-v-2.6": 0.719, "internvl2-8b": 0.328,
                   "internvl2-26b": 0.449}


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n_req = 40 if quick else 100
    for model, rate in RATES.items():
        cfg = get_config(model)
        for n_img in ((2,) if quick else (2, 4)):
            reqs = poisson_requests(cfg, WorkloadSpec(
                rate=rate, n_requests=n_req, n_items=n_img, output_len=10))
            stats = {}
            for sysname, spec, irp in (("EPD", EPD_SPEC, True),
                                       ("DistServe", DIST_SPEC, False)):
                out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                                cfg, A100_80G, reqs)
                t = np.array([r.ttft for r in out])
                stats[sysname] = t
                rows.append(Row(
                    f"fig6/{model}/img{n_img}/{sysname}", us,
                    round(float(t.mean()), 4),
                    {"p50": float(np.percentile(t, 50)),
                     "p95": float(np.percentile(t, 95))}))
            red = 1 - stats["EPD"].mean() / stats["DistServe"].mean()
            rows.append(Row(
                f"sec4.2/{model}/img{n_img}/ttft_reduction", 0.0,
                round(float(red), 3),
                {"paper_reduction_upto": PAPER_REDUCTION[model]}))
    rows.extend(run_engine_ttft(quick))
    rows.extend(run_engine_mm_cache(quick))
    rows.extend(run_engine_prefix_cache(quick))
    rows.extend(run_engine_overlap(quick))
    return rows


def run_engine_ttft(quick: bool = False) -> list[Row]:
    """Real-execution engine TTFT + decode tokens/s per decode-stage mode
    (paged-batched vs dense per-request), same reduced model + workload."""
    stats = engine_mode_stats(quick)
    rows = []
    for mode in ("paged", "dense"):
        s = stats[mode]
        rows.append(Row(f"engine_ttft/{mode}", s["wall_s"] * 1e6,
                        round(s["mean_ttft"], 4),
                        {"decode_tok_s": round(s["decode_tok_s"], 1),
                         "peak_cache_bytes": s["peak_cache_bytes"]}))
    return rows


def run_engine_mm_cache(quick: bool = False) -> list[Row]:
    """ψ_EP MMTokenCache rows (paper §3.2.1): repeated-image TTFT drops
    because the E stage is skipped entirely on the cache hit."""
    s = engine_mm_cache_stats(quick)
    return [
        Row("engine_mm_cache/first_seen_ttft", 0.0,
            round(s["ttft_first"], 4),
            {"encode_shards": s["encode_shards_first_seen"]}),
        Row("engine_mm_cache/repeat_ttft", 0.0,
            round(s["ttft_repeat"], 4),
            {"mm_cache_hit": s["repeat_hit"],
             "encode_shards_delta": (s["encode_shards_after_repeat"]
                                     - s["encode_shards_first_seen"])}),
        Row("engine_mm_cache/ttft_speedup_on_hit", 0.0,
            round(s["ttft_first"] / max(s["ttft_repeat"], 1e-9), 2),
            {"cache_hits": s["cache_hits"],
             "cache_misses": s["cache_misses"]}),
    ]


def run_engine_prefix_cache(quick: bool = False) -> list[Row]:
    """Block-level KV prefix cache rows: multi-turn chat + shared system
    prompt, cache-on vs cache-off. The on-run reuses full prefix blocks
    (prefix_tokens_reused > 0) and plans strictly fewer prefill chunk
    rows — ZERO for the block-aligned exact repeat."""
    s = engine_prefix_cache_stats(quick)
    rows = []
    for on in ("off", "on"):
        m = s[on]
        rows.append(Row(
            f"engine_prefix_cache/{on}", m["wall_s"] * 1e6,
            round(m["mean_shared_ttft"], 4),
            {"multi_turn_ttft": round(m["multi_turn_ttft"], 4),
             "repeat_ttft": round(m["repeat_ttft"], 4),
             "prefill_chunks": m["prefill_chunks"],
             "prefill_tokens": m["prefill_tokens"],
             "prefix_tokens_reused": m["prefix_tokens_reused"]}))
    rows.append(Row(
        "engine_prefix_cache/prefill_rows_saved", 0.0,
        s["off"]["prefill_chunks"] - s["on"]["prefill_chunks"],
        {"prefill_tokens_saved": (s["off"]["prefill_tokens"]
                                  - s["on"]["prefill_tokens"]),
         "cache_hits": s["on"]["prefix_cache_hits"]}))
    return rows


def run_engine_overlap(quick: bool = False) -> list[Row]:
    """Encode–prefill overlap + packed encode lane rows: a many-image
    prompt whose text prefix prefills chunk-by-chunk while ψ_EP shards
    stream in. Greedy outputs are bit-identical on vs off; the per-arm
    TTFT floor drops by the hidden encode tail."""
    s = engine_overlap_stats(quick)
    rows = []
    for on in ("off", "on"):
        m = s[on]
        rows.append(Row(
            f"engine_overlap/{on}", m["wall_s"] * 1e6,
            round(m["min_ttft"], 4),
            {"mean_ttft": round(m["mean_ttft"], 4),
             "median_ttft": round(m["median_ttft"], 4),
             "overlap_chunks_early": m["overlap_chunks_early"],
             "overlap_watermark_hwm": m["overlap_watermark_hwm"],
             "encode_lane_rows": m["encode_lane_rows"],
             "n_requests": m["n_requests"]}))
    rows.append(Row(
        "engine_overlap/ttft_reduction", 0.0,
        round(1 - s["on"]["min_ttft"] / max(s["off"]["min_ttft"], 1e-9), 3),
        {"bit_identical": s["bit_identical"]}))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine-only", action="store_true",
                    help="skip the simulator sweeps; run only the "
                         "real-execution engine TTFT + mm-cache rows")
    args = ap.parse_args()
    if args.engine_only:
        out = (run_engine_ttft(args.quick) + run_engine_mm_cache(args.quick)
               + run_engine_prefix_cache(args.quick)
               + run_engine_overlap(args.quick))
    else:
        out = run(args.quick)
    print("name,us_per_call,derived")
    for row in out:
        print(row.csv(), flush=True)
