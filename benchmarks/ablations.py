"""Tables 4, 5 and 6: IRP ablation, offline-optimizer ablation, dynamic
role-switching ablation."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.allocator import (goodput_objective, optimize_allocation,
                                  sample_configs)
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import WorkloadSpec, poisson_requests

from benchmarks.common import EPD_SPEC, Row, timed

CFG = get_config("minicpm-v-2.6")
PAPER_T4 = {2: (0.92, 1.46), 4: (1.02, 2.47), 6: (1.14, 3.37),
            8: (1.74, 4.27)}  # img -> (EPD, w/o IRP)


def run_irp(quick: bool) -> list[Row]:
    rows = []
    n = 40 if quick else 100
    for n_img, paper in PAPER_T4.items():
        reqs = poisson_requests(CFG, WorkloadSpec(
            rate=0.25, n_requests=n, n_items=n_img, output_len=10))
        on, us = timed(simulate, ClusterSpec(EPD_SPEC, irp=True),
                       CFG, A100_80G, reqs)
        off = simulate(ClusterSpec(EPD_SPEC, irp=False), CFG, A100_80G, reqs)
        t_on = float(np.mean([r.ttft for r in on]))
        t_off = float(np.mean([r.ttft for r in off]))
        rows.append(Row(f"table4/img{n_img}", us,
                        f"epd={t_on:.2f};no_irp={t_off:.2f}",
                        {"slowdown": round(t_off / t_on, 2),
                         "paper_epd": paper[0], "paper_no_irp": paper[1]}))
    return rows


def run_optimizer(quick: bool) -> list[Row]:
    """Table 5: optimizer-found config vs expected value of random configs
    (same 8-GPU budget). Paper: 2.2x goodput, 2.1x TTFT."""
    slo = SLO(3.90, 0.06)   # 6 images/request criteria (E.4 workload)
    n = 30 if quick else 60
    rates = [0.25, 0.5, 1.0] if quick else [0.25, 0.5, 1.0, 1.5, 2.0]

    def mk(rate):
        return poisson_requests(CFG, WorkloadSpec(
            rate=rate, n_requests=n, n_items=6, output_len=10, slo=slo))

    ev = goodput_objective(CFG, A100_80G, mk, slo, rates)
    res, us = timed(optimize_allocation, ev, n_gpus=8,
                    n_init=4 if quick else 8, n_iter=4 if quick else 12,
                    seed=0)
    rng = np.random.default_rng(7)
    rand = [ev(c) for c in sample_configs(rng, 5 if quick else 10,
                                          n_gpus=8)]
    # TTFT/TPOT at the optimum's goodput rate, as in App. E.4
    rate = max(res.best_score, rates[0])
    best_out = summarize(simulate(res.best.spec(), CFG, A100_80G, mk(rate)))
    return [
        Row("table5/goodput", us,
            f"opt={res.best_score};rand_mean={np.mean(rand):.2f}",
            {"ratio": round(res.best_score / max(np.mean(rand), 1e-9), 2),
             "paper_ratio": 2.2, "best_config": res.best.spec().spec}),
        Row("table5/ttft_at_goodput", 0.0, round(best_out.ttft_mean, 3),
            {"paper_epd": 2.12}),
        Row("table5/tpot_at_goodput", 0.0, round(best_out.tpot_mean, 4),
            {"paper_epd": 0.031}),
    ]


def run_role_switch(quick: bool) -> list[Row]:
    """Table 6: workload shifts from 50 to 500 output tokens; without
    switching the 5E1P2D config collapses. Paper: 2.2x latency, 2.4x TPOT."""
    slo = SLO(1.42, 0.05)
    n_long = 45 if quick else 90
    short = poisson_requests(CFG, WorkloadSpec(
        rate=3.0, n_requests=10, n_items=1, output_len=50, slo=slo))
    long_ = poisson_requests(CFG, WorkloadSpec(
        rate=3.0, n_requests=n_long, n_items=1, output_len=500, slo=slo,
        seed=1))
    for i, r in enumerate(long_):
        r.req_id = 1000 + i
        r.arrival += short[-1].arrival
    reqs = short + long_
    static, us = timed(simulate, ClusterSpec(
        "5E1P2D", role_switch=False, decode_batch=4), CFG, A100_80G, reqs)
    dyn = simulate(ClusterSpec("5E1P2D", role_switch=True, decode_batch=4),
                   CFG, A100_80G, reqs)
    s_s, s_d = summarize(static), summarize(dyn)
    return [
        Row("table6/latency", us,
            f"epd={s_d.latency_mean:.2f};no_switch={s_s.latency_mean:.2f}",
            {"ratio": round(s_s.latency_mean / s_d.latency_mean, 2),
             "paper": (28.01, 61.10)}),
        Row("table6/tpot", 0.0,
            f"epd={s_d.tpot_mean:.3f};no_switch={s_s.tpot_mean:.3f}",
            {"ratio": round(s_s.tpot_mean / s_d.tpot_mean, 2),
             "paper": (0.05, 0.12)}),
        Row("table6/ttft", 0.0,
            f"epd={s_d.ttft_mean:.2f};no_switch={s_s.ttft_mean:.2f}",
            {"paper": (1.42, 1.33)}),
    ]


def run(quick: bool = False) -> list[Row]:
    return run_irp(quick) + run_optimizer(quick) + run_role_switch(quick)
