"""Role-switch benchmark: goodput under a workload SHIFT (paper §3.2.4 /
Table 6), on the REAL multi-instance cluster engine.

Workload: an encode-heavy phase (multimodal payloads, short outputs)
followed by a decode-heavy phase (text-only, long outputs), run twice on
a "2E1P1D" cluster:

  static      role_switch off — the second E instance idles while the
              single D instance grinds through the decode backlog
  dynamic     role_switch on — the monitor observes the LoadEstimator's
              demand shift and re-roles an idle E instance to D
              (drain -> swap stage set/pools -> cooldown), doubling
              decode slots mid-run

Reported metrics are structural + throughput: completed requests (all
must finish — zero stranded), observed switches (>= 1 in the dynamic
run), decode tok/s over the shifted phase, and phase wall-clock for
reference only (this container's timings are noisy; CI asserts the
structural rows, never timing ratios).

    PYTHONPATH=src python benchmarks/role_switch.py [--quick]
"""
from __future__ import annotations

if __package__ in (None, ""):
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import time

import numpy as np

from benchmarks.common import Row

WALL_BOUND_S = 420.0       # --quick must finish inside this (CI smoke)


def role_switch_stats(quick: bool = False,
                      arch: str = "pixtral-12b") -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                               RequestState, ServeRequest)

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n_enc = 4 if quick else 8              # encode-heavy phase requests
    n_dec = 8 if quick else 16             # decode-heavy phase requests
    long_out = 24 if quick else 48

    out = {}
    for label, switch in (("static", False), ("dynamic", True)):
        rng = np.random.default_rng(0)
        clu = ClusterEngine(
            cfg, params,
            EngineConfig(n_encode_workers=2, max_new_tokens=long_out,
                         decode_batch=2),
            ClusterConfig(spec="2E1P1D", role_switch=switch,
                          monitor_interval=0.1, switch_cooldown=0.5))
        clu.start()
        rid = 0
        t0 = time.perf_counter()
        # ---- phase 1: encode-heavy (mm payloads, 2-token outputs)
        M = 2 * cfg.modality.tokens_per_item
        ids = []
        for _ in range(n_enc):
            clu.submit(ServeRequest(
                req_id=rid,
                prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                mm_embeds=rng.standard_normal(
                    (M, cfg.modality.enc_d_model)).astype(np.float32) * 0.1,
                mm_positions=np.arange(1, M + 1, dtype=np.int32),
                max_new_tokens=2))
            ids.append(rid)
            rid += 1
        outs = [clu.result(i, timeout=600) for i in ids]
        # ---- phase 2: decode-heavy (text-only, long outputs)
        t1 = time.perf_counter()
        tok0 = clu.stats["decode_tokens"]
        ids = []
        for _ in range(n_dec):
            clu.submit(ServeRequest(
                req_id=rid,
                prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=long_out))
            ids.append(rid)
            rid += 1
            time.sleep(0.01)
        outs += [clu.result(i, timeout=600) for i in ids]
        phase2_wall = time.perf_counter() - t1
        # let an in-flight re-role finish so the counters are final
        deadline = time.time() + 10.0
        while (switch and clu.stats["role_switches"] == 0
               and any(i._pending_role is not None for i in clu.instances)
               and time.time() < deadline):
            time.sleep(0.05)
        clu.stop()
        s = clu.stats
        done = sum(o.state is RequestState.DONE for o in outs)
        out[label] = {
            "completed": done,
            "stranded": len(outs) - done,
            "switches": s["role_switches"],
            "switch_log": list(clu.switch_log),
            "final_roles": clu.current_roles(),
            "phase2_decode_tokens": s["decode_tokens"] - tok0,
            "phase2_wall_s": phase2_wall,
            "phase2_tok_s": (s["decode_tokens"] - tok0) / max(phase2_wall,
                                                              1e-9),
            "pd_migrations": s["pd_migrations"],
            "role_seconds": dict(s["role_seconds"]),
            "total_wall_s": time.perf_counter() - t0,
        }
    return out


def run(quick: bool = False) -> list:
    """benchmarks.run entry point."""
    return rows(quick=quick)


def rows(quick: bool = False) -> list:
    st = role_switch_stats(quick=quick)
    out = []
    for label in ("static", "dynamic"):
        d = st[label]
        out.append(Row(
            name=f"role_switch/{label}",
            us_per_call=d["phase2_wall_s"] * 1e6,
            derived=f"{d['phase2_tok_s']:.1f} tok/s "
                    f"switches={d['switches']} stranded={d['stranded']}",
            extra=d))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    t0 = time.perf_counter()
    st = role_switch_stats(quick=args.quick)
    for label in ("static", "dynamic"):
        d = st[label]
        moves = ", ".join(f"i{i}:{o}->{n}" for _, i, o, n in
                          d["switch_log"][:4])
        print(f"{label:8s} completed={d['completed']:3d} "
              f"stranded={d['stranded']} switches={d['switches']} "
              f"phase2={d['phase2_tok_s']:7.1f} tok/s "
              f"roles={''.join(d['final_roles'])}"
              + (f"  [{moves}]" if moves else ""))

    # CI smoke assertions: structural only (never timing ratios)
    assert st["static"]["stranded"] == 0, "static run stranded requests"
    assert st["dynamic"]["stranded"] == 0, "dynamic run stranded requests"
    assert st["static"]["switches"] == 0
    assert st["dynamic"]["switches"] >= 1, \
        "dynamic run observed no role switch under the workload shift"
    first = st["dynamic"]["switch_log"][0]
    assert (first[2], first[3]) == ("E", "D"), first
    if args.quick:
        wall = time.perf_counter() - t0
        assert wall < WALL_BOUND_S, f"role-switch smoke too slow: {wall:.0f}s"
    print("role-switch benchmark OK")


if __name__ == "__main__":
    main()
