"""Roofline report (brief deliverable g): reads the dry-run JSONL records
and emits the per-(arch x shape x mesh) three-term roofline table."""
from __future__ import annotations

import json
import os

from benchmarks.common import Row

BASELINE = "runs/dryrun_baseline.jsonl"
MULTIPOD = "runs/dryrun_multipod.jsonl"
OPTIMIZED = "runs/dryrun_optimized.jsonl"


def _load(path):
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path) if l.strip()]


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    for path, mesh_tag in ((BASELINE, "16x16"), (MULTIPOD, "2x16x16"),
                           (OPTIMIZED, "opt")):
        recs = _load(path)
        for r in recs:
            t = r["roofline_s"]
            dom_ms = t[r["dominant"]] * 1e3
            rows.append(Row(
                f"roofline/{mesh_tag}/{r['arch']}/{r['shape']}"
                + (f"/{r['tag']}" if r.get("tag", "baseline") != "baseline"
                   else ""),
                dom_ms * 1e3,        # dominant term in µs
                r["dominant"],
                {"compute_ms": round(t["compute"] * 1e3, 3),
                 "memory_ms": round(t["memory"] * 1e3, 3),
                 "collective_ms": round(t["collective"] * 1e3, 3),
                 "useful_flop_ratio": round(r["useful_flop_ratio"], 3),
                 "sw_variant": r["sw_variant"]}))
        if recs:
            rows.append(Row(f"roofline/{mesh_tag}/records", 0.0, len(recs)))
    return rows
