"""Table 1: mean TTFT vs video length (8/16/32/64 frames) at 1 req/s,
Video-MME-style workload, MiniCPM-V 2.6. Paper: EPD 0.24/0.30/0.49/1.00 s
vs vLLM 0.42/0.82/1.59/3.11 s."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import A100_80G
from repro.core.cluster import ClusterSpec, simulate
from repro.data.workload import videomme_like

from benchmarks.common import DIST_SPEC, EPD_SPEC, Row, VLLM_SPEC, timed

PAPER = {  # frames -> (vLLM, DistServe, EPD)
    8: (0.42, 0.42, 0.24), 16: (0.82, 0.81, 0.30),
    32: (1.59, 1.54, 0.49), 64: (3.11, 3.08, 1.00),
}


def run(quick: bool = False) -> list[Row]:
    cfg = get_config("minicpm-v-2.6")
    rows: list[Row] = []
    n = 40 if quick else 100
    for frames, paper in PAPER.items():
        reqs = videomme_like(cfg, rate=1.0, n=n, n_frames=frames)
        for i, (sysname, spec, irp) in enumerate(
                (("vLLM", VLLM_SPEC, False), ("DistServe", DIST_SPEC, False),
                 ("EPD", EPD_SPEC, True))):
            out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                            cfg, A100_80G, reqs)
            ttft = float(np.mean([r.ttft for r in out]))
            rows.append(Row(f"table1/frames{frames}/{sysname}", us,
                            round(ttft, 3), {"paper": paper[i]}))
    return rows
