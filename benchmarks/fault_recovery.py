"""Fault-recovery benchmark: recovery time and SLO attainment under
injected instance faults, on the REAL multi-instance cluster engine.

Four scenarios on a text-only "1P2D" cluster (codeqwen reduced):

  baseline     no faults — the drain wall-clock every other row is read
               against
  kv-migrate   the first D instance dies mid-decode with its KV pool
               reachable: residents move to the surviving D via the
               byte-exact ψ_PD extract/inject path (greedy streams stay
               bit-identical)
  kv-replay    same death but the KV is declared lost: residents replay
               from the prompt through P (preemption-replay)
  straggler    no death — a 6x slowdown on one D under the
               latency-aware assigner, which sheds load off the limping
               instance

Reported metrics: completed/stranded counts, failover/replay counters,
recovery wall-clock (fault injection -> last request done) and SLO
attainment against a fixed per-request e2e budget. CI asserts the
structural rows (zero stranded, the right counter moved), never timing
ratios — this container's timings are noisy.

    PYTHONPATH=src python benchmarks/fault_recovery.py [--quick]
"""
from __future__ import annotations

if __package__ in (None, ""):
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)

import time

import numpy as np

from benchmarks.common import Row

WALL_BOUND_S = 420.0       # --quick must finish inside this (CI smoke)
SLO_E2E_S = 120.0          # generous per-request e2e budget (reduced model)

SCENARIOS = ("baseline", "kv-migrate", "kv-replay", "straggler")


def fault_recovery_stats(quick: bool = False,
                         arch: str = "codeqwen1.5-7b") -> dict:
    import jax
    from repro.configs import get_config
    from repro.core import Death, FaultPlan, Slowdown
    from repro.models import build_model
    from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                               RequestState, ServeRequest)

    cfg = get_config(arch).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n_req = 4 if quick else 8
    max_new = 12 if quick else 24

    def wait(pred, timeout=120.0, dt=0.02):
        deadline = time.time() + timeout
        while time.time() < deadline:
            if pred():
                return True
            time.sleep(dt)
        return False

    out = {}
    for label in SCENARIOS:
        rng = np.random.default_rng(0)
        policy = "latency_aware" if label == "straggler" else "least_loaded"
        clu = ClusterEngine(
            cfg, params,
            EngineConfig(n_encode_workers=1, max_new_tokens=max_new,
                         decode_batch=2, kv_blocks=32, kv_block_size=16,
                         max_seq_len=128),
            ClusterConfig(spec="1P2D", assign_policy=policy,
                          monitor_interval=0.1))
        if label == "straggler":
            # the limping instance is present from the start; the
            # latency-aware assigner observes its service EWMA and sheds
            clu.set_fault_plan(FaultPlan(slowdowns=[
                Slowdown(iid=1, start=0.0, factor=6.0)]))
        clu.start()
        t0 = time.perf_counter()
        submit_t = {}
        reqs = []
        try:
            for i in range(n_req):
                r = ServeRequest(
                    req_id=i,
                    prompt=rng.integers(0, cfg.vocab, 15).astype(np.int32),
                    max_new_tokens=max_new)
                submit_t[i] = time.perf_counter()
                clu.submit(r)
                reqs.append(r)
            t_fault = None
            if label in ("kv-migrate", "kv-replay"):
                # steady state first: every request handed to a decode pool
                assert wait(
                    lambda: clu.stats["pd_migrations"] >= n_req), \
                    "requests never reached decode"
                t_fault = time.perf_counter()
                clu.set_fault_plan(FaultPlan(deaths=[Death(
                    iid=1, at=0.0,
                    kv_reachable=(label == "kv-migrate"))]))
            lat = {}
            outs = []
            for r in reqs:
                outs.append(clu.result(r.req_id, timeout=600))
                lat[r.req_id] = time.perf_counter() - submit_t[r.req_id]
            t_done = time.perf_counter()
        finally:
            clu.stop()
        s = clu.stats
        done = sum(o.state is RequestState.DONE for o in outs)
        out[label] = {
            "completed": done,
            "stranded": len(outs) - done,
            "instance_deaths": s["instance_deaths"],
            "fault_failovers": s["fault_failovers"],
            "fault_replays": s["fault_replays"],
            "jobs_rerouted": s["jobs_rerouted"],
            "preemptions": s["preemptions"],
            "recovery_s": (t_done - t_fault) if t_fault is not None
            else None,
            "slo_attainment": sum(v <= SLO_E2E_S for v in lat.values())
            / max(len(lat), 1),
            "latency_mean_s": sum(lat.values()) / max(len(lat), 1),
            "total_wall_s": t_done - t0,
        }
    return out


def run(quick: bool = False) -> list:
    """benchmarks.run entry point."""
    return rows(quick=quick)


def rows(quick: bool = False) -> list:
    st = fault_recovery_stats(quick=quick)
    out = []
    for label in SCENARIOS:
        d = st[label]
        rec = (f"recovery={d['recovery_s']:.2f}s "
               if d["recovery_s"] is not None else "")
        out.append(Row(
            name=f"fault_recovery/{label}",
            us_per_call=d["total_wall_s"] * 1e6,
            derived=f"{rec}slo={d['slo_attainment']:.2f} "
                    f"failovers={d['fault_failovers']} "
                    f"replays={d['fault_replays']} "
                    f"stranded={d['stranded']}",
            extra=d))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    t0 = time.perf_counter()
    st = fault_recovery_stats(quick=args.quick)
    for label in SCENARIOS:
        d = st[label]
        rec = (f"recovery={d['recovery_s']:6.2f}s"
               if d["recovery_s"] is not None else "recovery=     -")
        print(f"{label:11s} completed={d['completed']:3d} "
              f"stranded={d['stranded']} deaths={d['instance_deaths']} "
              f"failovers={d['fault_failovers']} "
              f"replays={d['fault_replays']} {rec} "
              f"slo={d['slo_attainment']:.2f} "
              f"lat={d['latency_mean_s']:.2f}s")

    # CI smoke assertions: structural only (never timing ratios)
    for label in SCENARIOS:
        assert st[label]["stranded"] == 0, f"{label}: stranded requests"
    assert st["baseline"]["instance_deaths"] == 0
    assert st["kv-migrate"]["instance_deaths"] == 1
    assert st["kv-migrate"]["fault_failovers"] >= 1
    assert st["kv-migrate"]["fault_replays"] == 0
    assert st["kv-replay"]["instance_deaths"] == 1
    assert st["kv-replay"]["fault_replays"] >= 1
    assert st["straggler"]["instance_deaths"] == 0
    if args.quick:
        wall = time.perf_counter() - t0
        assert wall < WALL_BOUND_S, \
            f"fault-recovery smoke too slow: {wall:.0f}s"
    print("fault-recovery benchmark OK")


if __name__ == "__main__":
    main()
