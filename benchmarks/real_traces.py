"""Figures 7 & 8: SLO attainment on NextQA / Video-MME trace statistics
(MiniCPM-V 2.6; NextQA SLO TTFT=5.60 TPOT=0.06, Video-MME TTFT=3.1
TPOT=0.025, 64 frames)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import A100_80G, SLO
from repro.core.cluster import ClusterSpec, simulate, summarize
from repro.data.workload import nextqa_like, videomme_like

from benchmarks.common import DIST_SPEC, EPD_SPEC, Row, VLLM_SPEC, timed

SYSTEMS = {"EPD": (EPD_SPEC, True), "DistServe": (DIST_SPEC, False),
           "vLLM": (VLLM_SPEC, False)}


def run(quick: bool = False) -> list[Row]:
    cfg = get_config("minicpm-v-2.6")
    rows: list[Row] = []
    n = 40 if quick else 100
    rates = (0.25, 0.5) if quick else (0.1, 0.25, 0.5, 1.0, 2.0)
    traces = {
        "fig7_nextqa": (nextqa_like, SLO(5.60, 0.06), {}),
        "fig8_videomme": (videomme_like, SLO(3.10, 0.025), {"n_frames": 64}),
    }
    for tname, (gen, slo, kw) in traces.items():
        for rate in rates:
            reqs = gen(cfg, rate, n, slo=slo, **kw)
            for sysname, (spec, irp) in SYSTEMS.items():
                out, us = timed(simulate, ClusterSpec(spec, irp=irp),
                                cfg, A100_80G, reqs)
                s = summarize(out, slo)
                rows.append(Row(f"{tname}/rate{rate}/{sysname}", us,
                                round(s.slo_attainment, 3),
                                {"ttft_mean": s.ttft_mean}))
    return rows
